"""Train a ~100M-param LM for a few hundred steps on CPU with the full
substrate: sharded step, synthetic data pipeline with prefetch, periodic
checkpoints, crash-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

The default config is a width-reduced qwen2 (~large smoke). `--arch` accepts
any assigned architecture; `--full` uses the exact paper config (pod-scale —
only sensible on real hardware, but the code path is identical).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    losses = train(args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, smoke=not args.full, ckpt_dir=args.ckpt,
                   ckpt_every=50)
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps (checkpoints in {args.ckpt})")


if __name__ == "__main__":
    main()
