"""Quickstart: serve real models through the Clockwork controller on CPU.

Starts an in-process cluster (controller + one worker with a JAX backend),
registers two models (a reduced ResNet-50 — the paper's eval model — and an
LM decode engine), submits batched requests, and prints latency/goodput.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.actions import Request
from repro.core.clock import EventLoop, RealClock
from repro.core.controller import Controller
from repro.core.scheduler import ClockworkScheduler
from repro.core.worker import Worker
from repro.serving.engine import (JaxBackend, make_lm_decode_model,
                                  make_resnet_model)
from repro.utils import welford_summary


def main():
    loop = EventLoop(RealClock())
    print("[quickstart] compiling model batch buckets (AOT, like the "
          "paper's per-batch-size TVM kernels)...")
    engines = {
        "resnet50_mini": make_resnet_model("resnet50_mini", scale=16,
                                           batches=(1, 2, 4)),
        "qwen2_decode": make_lm_decode_model("qwen2_decode", "qwen2-0.5b",
                                             batches=(1, 2, 4), ctx=128),
    }
    models = {k: v.modeldef() for k, v in engines.items()}
    backend = JaxBackend(engines)
    worker = Worker("w0", loop, backend, models, n_gpus=1)
    controller = Controller(loop, models, ClockworkScheduler(),
                            action_delay=1e-4)
    profiles = {}
    for e in engines.values():
        profiles.update(e.seed_profiles())
    controller.add_worker(worker, profiles)

    done = []
    controller.on_response = done.append

    slo = 2.0  # generous on a shared CPU; the controller still *schedules*
    print("[quickstart] submitting 30 requests across 2 models...")
    for i in range(30):
        controller.on_request(Request(model_id=list(models)[i % 2],
                                      arrival=loop.now(), slo=slo))
        loop.run_until(loop.now() + 0.01)
    loop.run_until(loop.now() + 5.0)

    ok = [r for r in done if r.status == "ok"]
    lat = [r.completion - r.arrival for r in ok]
    print(f"[quickstart] {len(ok)}/{len(done)} within SLO; latency stats "
          f"(s): {welford_summary(lat)}")
    for mid in models:
        est = controller.profiler.estimate("INFER", mid, 1)
        print(f"[quickstart] learned INFER profile {mid} b1: "
              f"{est * 1e3:.2f} ms")


if __name__ == "__main__":
    main()
