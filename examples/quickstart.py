"""Quickstart: serve real models through the Clockwork controller on CPU.

Starts an in-process cluster (controller + one worker with a JAX backend),
registers two models (a reduced ResNet-50 — the paper's eval model — and an
LM decode engine), submits batched requests, and prints latency/goodput.

Profiles persist across runs: the first run measures (or you pre-measure
with `python -m repro.telemetry.profiler`) and writes
experiments/profiles.json; repeat runs seed from it and skip warmup.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.actions import Request
from repro.core.clock import EventLoop, RealClock
from repro.core.controller import Controller
from repro.core.scheduler import ClockworkScheduler
from repro.core.worker import Worker
from repro.serving.engine import (JaxBackend, make_lm_decode_model,
                                  make_resnet_model, seed_engines,
                                  update_store)
from repro.telemetry import ProfileStore
from repro.utils import welford_summary

STORE_PATH = "experiments/profiles.json"


def main():
    loop = EventLoop(RealClock())
    print("[quickstart] compiling model batch buckets (AOT, like the "
          "paper's per-batch-size TVM kernels)...")
    engines = {
        "resnet50_mini": make_resnet_model("resnet50_mini", scale=16,
                                           batches=(1, 2, 4)),
        "qwen2_decode": make_lm_decode_model("qwen2_decode", "qwen2-0.5b",
                                             batches=(1, 2, 4), ctx=128),
    }
    store = ProfileStore.load_if_exists(STORE_PATH)
    if store is not None:
        print(f"[quickstart] seeding profiles from {STORE_PATH} "
              "(skipping warmup re-measurement)")
    profiles = seed_engines(engines, store)
    for e in engines.values():
        if e.warmup_count == 0:   # store-seeded: warmup didn't compile it
            e.compile()   # AOT, untimed — keeps compiles off the hot path
    models = {k: v.modeldef() for k, v in engines.items()}
    backend = JaxBackend(engines)
    worker = Worker("w0", loop, backend, models, n_gpus=1)
    controller = Controller(loop, models, ClockworkScheduler(),
                            action_delay=1e-4)
    controller.add_worker(worker, profiles)

    done = []
    controller.on_response = done.append

    slo = 2.0  # generous on a shared CPU; the controller still *schedules*
    print("[quickstart] submitting 30 requests across 2 models...")
    for i in range(30):
        controller.on_request(Request(model_id=list(models)[i % 2],
                                      arrival=loop.now(), slo=slo))
        loop.run_until(loop.now() + 0.01)
    loop.run_until(loop.now() + 5.0)

    ok = [r for r in done if r.status == "ok"]
    lat = [r.completion - r.arrival for r in ok]
    print(f"[quickstart] {len(ok)}/{len(done)} within SLO; latency stats "
          f"(s): {welford_summary(lat)}")
    for mid in models:
        est = controller.profiler.estimate("INFER", mid, 1)
        print(f"[quickstart] learned INFER profile {mid} b1: "
              f"{est * 1e3:.2f} ms")

    rep = controller.telemetry_report()
    bd = rep["breakdown"]
    print(f"[quickstart] latency breakdown (median s): "
          f"queue={bd['queue']['median']:.4f} "
          f"exec={bd['exec']['median']:.4f} "
          f"total={bd['total']['median']:.4f}; "
          f"cold_starts={bd['cold_starts']}")
    update_store(engines, store or ProfileStore(), controller) \
        .save(STORE_PATH)
    print(f"[quickstart] profiles persisted -> {STORE_PATH}")


if __name__ == "__main__":
    main()
