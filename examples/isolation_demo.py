"""Performance-isolation demo (paper Fig 7 right): latency-sensitive clients
keep meeting tight SLOs while batch clients saturate the same cluster.

    PYTHONPATH=src python examples/isolation_demo.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.scheduler import ClockworkScheduler
from repro.serving.simulator import build_cluster, table1_modeldef
from repro.serving.workload import ClosedLoopClient, OpenLoopClient


def run(with_batch_clients: bool, dur: float = 10.0):
    models = {f"ls{i}": table1_modeldef(f"ls{i}") for i in range(3)}
    models.update({f"bc{i}": table1_modeldef(f"bc{i}") for i in range(6)})
    cl = build_cluster(models, n_workers=2, scheduler=ClockworkScheduler())
    clients = [OpenLoopClient(cl.loop, cl.submit, f"ls{i}", 0.050,
                              rate=150.0, stop=dur, seed=i)
               for i in range(3)]
    if with_batch_clients:
        clients += [ClosedLoopClient(cl.loop, cl.submit, f"bc{i}", 10.0,
                                     concurrency=16) for i in range(6)]
    cl.attach_clients(clients)
    cl.run(dur + 0.5)
    ls_ok = sum(1 for r in cl.controller.completed
                if r.model_id.startswith("ls") and r.status == "ok")
    ls_all = max(1, sum(1 for r in cl.controller.completed
                        if r.model_id.startswith("ls")))
    bc_ok = sum(1 for r in cl.controller.completed
                if r.model_id.startswith("bc") and r.status == "ok")
    return ls_ok / ls_all, bc_ok / dur


def main():
    alone, _ = run(False)
    shared, bc = run(True)
    print("[isolation] latency-sensitive satisfaction, 50 ms SLO:")
    print(f"  LS alone                : {alone:.4f}")
    print(f"  LS + saturating batch   : {shared:.4f}")
    print(f"  batch-client throughput : {bc:.0f} r/s (scheduled into idle "
          f"gaps)")


if __name__ == "__main__":
    main()
