"""End-to-end serving driver: replay an MAF-like trace against a simulated
multi-worker cluster (paper §6.5) and print the goodput/latency report.

    PYTHONPATH=src python examples/serve_trace.py [--models 60] [--dur 30]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.scheduler import ClockworkScheduler
from repro.serving.simulator import TimeSeries, build_cluster, table1_modeldef
from repro.serving.workload import VariableRateClient, maf_like_rates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", type=int, default=60)
    ap.add_argument("--dur", type=float, default=30.0)
    ap.add_argument("--rate", type=float, default=600.0)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--slo-ms", type=float, default=100.0)
    args = ap.parse_args()

    rates = maf_like_rates(args.models, args.rate, args.dur, seed=4)
    models = {mid: table1_modeldef(mid) for mid in rates}
    cl = build_cluster(models, n_workers=args.workers,
                       scheduler=ClockworkScheduler())
    clients = [VariableRateClient(cl.loop, cl.submit, mid, args.slo_ms / 1e3,
                                  fn, stop=args.dur, seed=i,
                                  max_rate=args.rate / 4)
               for i, (mid, fn) in enumerate(rates.items())]
    cl.attach_clients(clients)
    ts = TimeSeries(cl, dt=max(args.dur / 20, 1.0))
    s = cl.run(args.dur + 1.0)

    print(f"[serve_trace] {args.models} models, {args.workers} workers, "
          f"SLO {args.slo_ms:.0f} ms")
    total = max(1, s["goodput"] + s["timeout"] + s["rejected"])
    print(f"  goodput      : {s['goodput'] / args.dur:8.1f} r/s "
          f"({s['goodput'] / total:.5f} of all requests)")
    print(f"  timeouts     : {s['timeout']}")
    print(f"  rejected     : {s['rejected']} (proactive, before execution)")
    print(f"  p50/p99/max  : {s['p50'] * 1e3:.1f} / {s['p99'] * 1e3:.1f} / "
          f"{s['max'] * 1e3:.1f} ms")
    print("  timeline (t, goodput r/s, p99 ms):")
    for x in ts.samples:
        p99 = f"{x['p99'] * 1e3:6.1f}" if x["p99"] else "   n/a"
        print(f"    t={x['t']:6.1f}  {x['goodput_rs']:8.1f}  {p99}")


if __name__ == "__main__":
    main()
