"""Distributed serving demo: a controller and N worker daemons in
separate OS processes, talking the runtime wire protocol over TCP.

    PYTHONPATH=src python examples/serve_distributed.py --workers 2

spawns `python -m repro.runtime.worker` subprocesses, waits for them to
register, serves a short open-loop workload under real time, prints a
JSON summary (goodput, latency percentiles, per-worker network-delay
estimates, telemetry counts), then winds the daemons down gracefully —
each flushes its buffered telemetry before leaving.

`--loadgen` completes the paper's three-tier topology: instead of
in-process clients, a `python -m repro.runtime.loadgen` subprocess (with
`--loadgen-processes` child generators) drives the controller over its
own TCP connections and reports *client-observed* goodput and latency —
the summary then carries both the controller's and the clients' view.

`--smoke` makes the run assert (goodput > 0, zero timeouts' spirit —
completed-late must be 0 by construction, workers exit 0, and with
`--loadgen` nonzero client-observed goodput) so CI can use it as the
distributed smoke job.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.core.clock import EventLoop, RealClock, RealtimePump
from repro.core.controller import Controller
from repro.core.scheduler import ClockworkScheduler
from repro.runtime.controller import ControllerServer
from repro.runtime.worker import demo_models
from repro.serving.workload import OpenLoopClient


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--n-models", type=int, default=4)
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="open-loop request rate per model (r/s)")
    ap.add_argument("--slo", type=float, default=0.25)
    ap.add_argument("--port", type=int, default=0,
                    help="controller TCP port (0 = ephemeral)")
    ap.add_argument("--smoke", action="store_true",
                    help="assert goodput/clean-shutdown (CI smoke job)")
    ap.add_argument("--telemetry-jsonl", default=None,
                    help="daemons stream telemetry JSONL next to this "
                         "prefix (one file per worker)")
    ap.add_argument("--loadgen", action="store_true",
                    help="drive the workload from a separate loadgen "
                         "process (full three-tier topology) instead of "
                         "in-process clients")
    ap.add_argument("--loadgen-processes", type=int, default=2,
                    help="child generator processes under --loadgen")
    ap.add_argument("--workload", default="open",
                    choices=("open", "closed", "maf"),
                    help="workload shape for --loadgen")
    args = ap.parse_args(argv)

    models = demo_models(args.n_models)
    loop = EventLoop(RealClock())
    pump = RealtimePump(loop, max_poll=0.005)
    # generous result grace: wall-clock scheduling slop must not look like
    # a missed result (virtual-clock defaults are tighter)
    controller = Controller(loop, models, ClockworkScheduler(),
                            action_delay=0.002, result_grace=0.25,
                            default_slo=args.slo)
    server = ControllerServer(controller)
    port = server.listen_tcp("127.0.0.1", args.port, pump.post)
    print(f"[controller] listening on 127.0.0.1:{port}", flush=True)

    env = dict(os.environ)
    procs = []
    lg = None
    for i in range(args.workers):
        cmd = [sys.executable, "-m", "repro.runtime.worker",
               "--controller", f"127.0.0.1:{port}",
               "--worker-id", f"w{i}", "--n-models", str(args.n_models),
               "--seed", str(i),
               "--duration", str(args.duration + 30.0)]
        if args.telemetry_jsonl:
            cmd += ["--telemetry-jsonl", f"{args.telemetry_jsonl}.w{i}"]
        procs.append(subprocess.Popen(cmd, env=env))

    try:
        ok = pump.run(until=lambda: len(controller.workers) >= args.workers,
                      timeout=30.0)
        if not ok:
            print("FATAL: workers never registered", file=sys.stderr)
            return 2
        print(f"[controller] {len(controller.workers)} workers registered",
              flush=True)

        clients, client_out = [], None
        controller.start_heartbeats()
        if args.loadgen:
            # third tier: the workload lives in its own process(es) and
            # measures latency on its side of the network
            lg_cmd = [sys.executable, "-m", "repro.runtime.loadgen",
                      "--controller", f"127.0.0.1:{port}",
                      "--workload", args.workload,
                      "--n-models", str(args.n_models),
                      "--rate", str(args.rate), "--slo", str(args.slo),
                      "--duration", str(args.duration),
                      "--processes", str(args.loadgen_processes)]
            lg = subprocess.Popen(lg_cmd, env=env, stdout=subprocess.PIPE,
                                  text=True)
            pump.run(until=lambda: lg.poll() is not None,
                     timeout=args.duration + 90.0)
            try:
                lg_stdout, _ = lg.communicate(timeout=10.0)
            except subprocess.TimeoutExpired:
                lg.kill()
                lg_stdout, _ = lg.communicate()
            if not lg_stdout.strip():
                print("FATAL: loadgen produced no output", file=sys.stderr)
                return 3
            client_out = json.loads(lg_stdout)
            client_out["returncode"] = lg.returncode
        else:
            now = loop.now()
            clients = [OpenLoopClient(loop, controller.on_request, mid,
                                      args.slo, rate=args.rate, start=now,
                                      stop=now + args.duration, seed=i)
                       for i, mid in enumerate(models)]
            pump.run(timeout=args.duration + 0.5)

        summary = controller.summary()
        net = {wid: round(m.net_delay * 1e6)
               for wid, m in controller.workers.items()}
    finally:
        if lg is not None and lg.poll() is None:
            lg.kill()              # never orphan the loadgen tree
        server.shutdown()          # daemons flush telemetry and leave
        pump.run(timeout=1.0)      # let final TELEMETRY/GOODBYE frames land
        pump.stop()
        report = controller.telemetry_report()
        worker_gauges = sorted(k for k in report["gauges"]
                               if k.startswith("worker/"))
        rcs = []
        for pr in procs:
            try:
                rcs.append(pr.wait(timeout=10.0))
            except subprocess.TimeoutExpired:
                pr.kill()
                rcs.append(-9)

    sent = client_out["sent"] if client_out is not None \
        else sum(c.sent for c in clients)
    out = {"sent": sent, **summary,
           "net_delay_us": net, "worker_returncodes": rcs,
           "worker_gauges": worker_gauges}
    if client_out is not None:
        out["client"] = client_out
    print(json.dumps(out, indent=2, default=str))

    if args.smoke:
        assert out["goodput"] > 0, "no requests served"
        assert out["timeout"] == 0, "Clockwork must never respond late"
        assert all(rc == 0 for rc in rcs), f"unclean worker exit: {rcs}"
        assert out["dead_workers"] == 0, "worker falsely declared dead"
        assert worker_gauges, "daemon telemetry never reached controller"
        if client_out is not None:
            assert client_out["returncode"] == 0, "loadgen exited unclean"
            assert client_out["goodput"] > 0, \
                "no client-observed completions"
            assert client_out["timeout"] == 0, \
                "client observed a late response"
            assert client_out["goodput"] == out["goodput"], \
                "client/controller goodput disagree"
        print("SMOKE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
