"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # standard sizes
    BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run   # CI sizes

Prints ``name,us_per_call,derived`` CSV lines; richer per-figure CSVs land
in experiments/bench/.
"""
from __future__ import annotations

import os
import time
import traceback


def main() -> None:
    quick = os.environ.get("BENCH_QUICK", "0") == "1"
    from benchmarks import (bench_scheduler, bench_simulator,
                            fig2_predictability, fig5_goodput_vs_slo,
                            fig6_scale_up, fig7_slo_ladder, fig8_maf_trace,
                            fig9_prediction_error, lm_serving_v5e, roofline,
                            table1_model_profiles)
    benches = [
        ("bench_scheduler", bench_scheduler.run),
        ("bench_simulator", bench_simulator.run),
        ("fig2_predictability", fig2_predictability.run),
        ("table1_model_profiles", table1_model_profiles.run),
        ("fig5_goodput_vs_slo", fig5_goodput_vs_slo.run),
        ("fig6_scale_up", fig6_scale_up.run),
        ("fig7_slo_ladder", fig7_slo_ladder.run),
        ("fig8_maf_trace", fig8_maf_trace.run),
        ("fig9_prediction_error", fig9_prediction_error.run),
        ("roofline", roofline.run),
        ("lm_serving_v5e", lm_serving_v5e.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        t0 = time.time()
        try:
            fn(quick=quick)
            print(f"{name}_wallclock,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name}_wallclock,{(time.time() - t0) * 1e6:.0f},"
                  f"FAILED:{type(e).__name__}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
