"""Fig 7: (left) how low can SLOs go — workload satisfaction as the SLO
multiplier climbs; (right) latency-sensitive vs batch-client isolation."""
from __future__ import annotations

from benchmarks.common import report_line, write_csv
from repro.core.scheduler import ClockworkScheduler
from repro.serving.simulator import build_cluster, table1_modeldef
from repro.serving.workload import ClosedLoopClient, OpenLoopClient

B1_MS = 2.73  # paper's batch-1 resnet50 exec latency


def ladder(n_models: int, total_rate: float, n_workers: int, dur_per: float):
    models = {f"m{i}": table1_modeldef(f"m{i}") for i in range(n_models)}
    rows = []
    mult = 1.0
    while mult <= 100.0:
        slo = B1_MS / 1e3 * mult
        cl = build_cluster(models, n_workers=n_workers,
                           scheduler=ClockworkScheduler(),
                           preload=list(models) * n_workers)
        clients = [OpenLoopClient(cl.loop, cl.submit, mid, slo,
                                  rate=total_rate / n_models, stop=dur_per,
                                  seed=i)
                   for i, mid in enumerate(models)]
        cl.attach_clients(clients)
        s = cl.run(dur_per + 0.5)
        total = max(1, s["goodput"] + s["timeout"] + s["rejected"])
        rows.append((mult, slo * 1e3, s["goodput"] / total))
        mult *= 1.5
    return rows


def run(quick: bool = False):
    dur = 3.0 if quick else 8.0
    out = []
    for (n, rate, workers) in [(12, 600.0, 2), (12, 1200.0, 2),
                               (12, 2400.0, 2)] if not quick else \
                              [(6, 300.0, 2), (6, 900.0, 2)]:
        rows = ladder(n, rate, workers, dur)
        for mult, slo_ms, sat in rows:
            out.append((n, rate, mult, slo_ms, sat))
        min_ok = next((m for (m, _, s) in rows if s >= 0.99), None)
        report_line(f"fig7_min_slo_R{int(rate)}", 0.0,
                    f"min_mult_99pct={min_ok}")
    write_csv("fig7_slo_ladder", out,
              ["n_models", "rate_rs", "slo_mult", "slo_ms", "satisfaction"])

    # --- right: LS/BC isolation
    models = {f"ls{i}": table1_modeldef(f"ls{i}") for i in range(3)}
    models.update({f"bc{i}": table1_modeldef(f"bc{i}") for i in range(6)})

    def iso(with_bc: bool):
        cl = build_cluster(models, n_workers=2,
                           scheduler=ClockworkScheduler())
        clients = [OpenLoopClient(cl.loop, cl.submit, f"ls{i}", 0.050,
                                  rate=120.0, stop=dur, seed=i)
                   for i in range(3)]
        if with_bc:
            clients += [ClosedLoopClient(cl.loop, cl.submit, f"bc{i}",
                                         10.0, concurrency=16)
                        for i in range(6)]
        cl.attach_clients(clients)
        cl.run(dur + 0.5)
        ls_ok = sum(1 for r in cl.controller.completed
                    if r.model_id.startswith("ls") and r.status == "ok")
        ls_all = max(1, sum(1 for r in cl.controller.completed
                            if r.model_id.startswith("ls")))
        bc = sum(1 for r in cl.controller.completed
                 if r.model_id.startswith("bc") and r.status == "ok")
        return ls_ok / ls_all, bc / dur

    alone, _ = iso(False)
    shared, bc_rate = iso(True)
    report_line("fig7_isolation", 0.0,
                f"ls_sat_alone={alone:.3f};ls_sat_shared={shared:.3f};"
                f"bc_throughput={bc_rate:.0f}r/s")
    return out
