"""Fig 9: action-latency prediction error (over- vs under-prediction CDFs)
from a sustained mixed run — computed end-to-end from the telemetry
Recorder's ActionRecords (predicted vs actual per action), not from the
profiler's internal error lists."""
from __future__ import annotations

from benchmarks.common import report_line, write_csv
from repro.core.scheduler import ClockworkScheduler
from repro.serving.simulator import build_cluster, table1_modeldef
from repro.serving.workload import ClosedLoopClient
from repro.telemetry.reports import prediction_error_report


def run(quick: bool = False):
    dur = 8.0 if quick else 25.0
    models = {f"m{i}": table1_modeldef(f"m{i}") for i in range(6)}
    cl = build_cluster(models, n_workers=2, device_memory=1.5e9,  # churn
                      scheduler=ClockworkScheduler(), noise=0.0005,
                      spike_prob=0.0005, spike_scale=5.0)
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.100,
                                concurrency=8) for mid in models]
    cl.attach_clients(clients)
    cl.run(dur)

    rep = prediction_error_report(cl.recorder.iter_actions())
    over, under = rep["over"], rep["under"]
    write_csv("fig9_prediction_error",
              [("over", over["n"], over["p99_us"], over["max_us"]),
               ("under", under["n"], under["p99_us"], under["max_us"])],
              ["kind", "n", "p99_us", "max_us"])
    report_line("fig9_prediction_error", 0.0,
                f"over_p99_us={over['p99_us']:.0f};"
                f"under_p99_us={under['p99_us']:.0f};"
                f"n={over['n'] + under['n']}")
    return {"over_p99_us": over["p99_us"], "under_p99_us": under["p99_us"]}
