"""Fig 9: action-latency prediction error (over- vs under-prediction CDFs)
and completion-time error, from a sustained mixed run."""
from __future__ import annotations

import numpy as np

from benchmarks.common import report_line, write_csv
from repro.core.actions import ActionType
from repro.core.scheduler import ClockworkScheduler
from repro.serving.simulator import build_cluster, table1_modeldef
from repro.serving.workload import ClosedLoopClient


def run(quick: bool = False):
    dur = 8.0 if quick else 25.0
    models = {f"m{i}": table1_modeldef(f"m{i}") for i in range(6)}
    cl = build_cluster(models, n_workers=2, device_memory=1.5e9,  # churn
                       scheduler=ClockworkScheduler(), noise=0.0005,
                       spike_prob=0.0005, spike_scale=5.0)
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.100,
                                concurrency=8) for mid in models]
    cl.attach_clients(clients)
    cl.run(dur)
    prof = cl.controller.profiler

    def stats(xs):
        if not xs:
            return (0, 0.0, 0.0)
        a = np.asarray(xs)
        return (len(a), float(np.percentile(a, 99) * 1e6),
                float(a.max() * 1e6))

    n_o, p99_o, max_o = stats(prof.over_errors)
    n_u, p99_u, max_u = stats(prof.under_errors)
    write_csv("fig9_prediction_error",
              [("over", n_o, p99_o, max_o), ("under", n_u, p99_u, max_u)],
              ["kind", "n", "p99_us", "max_us"])
    report_line("fig9_prediction_error", 0.0,
                f"over_p99_us={p99_o:.0f};under_p99_us={p99_u:.0f};"
                f"n={n_o + n_u}")
    return {"over_p99_us": p99_o, "under_p99_us": p99_u}
