"""Fig 6: one worker serving (scaled-down) thousands of models.

Major workload: models activate one per second, sharing a fixed aggregate
rate (batching opportunities vanish, then device memory overflows and
LOAD/UNLOAD churn moves the bottleneck to the host->device link). Minor
workload: one sustained model that must keep its goodput throughout.
"""
from __future__ import annotations

from benchmarks.common import report_line, write_csv
from repro.core.scheduler import ClockworkScheduler
from repro.serving.simulator import TimeSeries, build_cluster, table1_modeldef
from repro.serving.workload import OpenLoopClient, VariableRateClient


def run(quick: bool = False):
    n_major = 40 if quick else 120
    major_rate = 300.0 if quick else 500.0
    dur = float(n_major + 10)
    models = {f"m{i}": table1_modeldef(f"m{i}") for i in range(n_major)}
    models["minor"] = table1_modeldef("minor")
    # small device memory: ~24 resident models max -> guaranteed churn
    cl = build_cluster(models, device_memory=2.7e9,
                       scheduler=ClockworkScheduler())

    def make_rate(i):
        def rate(t, i=i):
            active = max(1, min(n_major, int(t)))   # one activation per sec
            return major_rate / active if i < active else 0.0
        return rate

    clients = [VariableRateClient(cl.loop, cl.submit, f"m{i}", 0.100,
                                  make_rate(i), stop=dur, seed=i,
                                  max_rate=major_rate)
               for i in range(n_major)]
    clients.append(OpenLoopClient(cl.loop, cl.submit, "minor", 0.100,
                                  rate=60.0 if quick else 120.0, stop=dur,
                                  seed=999))
    cl.attach_clients(clients)
    ts = TimeSeries(cl, dt=2.0)
    s = cl.run(dur)

    loads = sum(1 for r in cl.controller.results_log
                if r.action_type.value == "LOAD"
                and r.status.value == "SUCCESS")
    minor_ok = sum(1 for r in cl.controller.completed
                   if r.model_id == "minor" and r.status == "ok")
    minor_all = max(1, sum(1 for r in cl.controller.completed
                           if r.model_id == "minor"))
    rows = [(x["t"], x["goodput_rs"], x["rejected_rs"],
             (x["p99"] or 0) * 1e3) for x in ts.samples]
    write_csv("fig6_scale_up", rows, ["t", "goodput_rs", "rejected_rs",
                                      "p99_ms"])
    maxlat = s["max"] * 1e3 if s["max"] == s["max"] else 0.0
    report_line("fig6_scale_up", 0.0,
                f"models={n_major + 1};goodput={s['goodput'] / dur:.0f}r/s;"
                f"loads={loads};minor_sat={minor_ok / minor_all:.3f};"
                f"max_latency_ms={maxlat:.1f};timeouts={s['timeout']}")
    return s
