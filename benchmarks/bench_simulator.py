"""Simulator throughput benchmark: how fast the discrete-event control
plane replays paper-scale serving workloads.

Sweeps (model count, aggregate request rate) points, runs the full
controller + workers + clients stack on the virtual clock, and reports:

  * requests simulated per wall-clock second (completed + rejected),
  * event-loop events dispatched per wall-clock second (`EventLoop.stats`),
  * simulated-seconds per wall-second (time compression ratio),
  * mean/p99 scheduler tick latency from the telemetry gauge stream.

Output: BENCH_simulator.json (see DESIGN.md §4 for how to read/update it).

Usage:
    PYTHONPATH=src python benchmarks/bench_simulator.py            # full
    PYTHONPATH=src python benchmarks/bench_simulator.py --smoke    # CI
    ... [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

from repro.core.scheduler import TICK_LATENCY_GAUGE, ClockworkScheduler
from repro.serving.simulator import PAPER_TABLE1, build_cluster, table1_modeldef
from repro.serving.workload import OpenLoopClient
from repro.telemetry.reports import quantile

FAMILIES = list(PAPER_TABLE1)

#            (n_models, total request rate r/s)
FULL_SWEEP = ((10, 500.0), (100, 1000.0), (500, 2000.0), (1000, 4000.0))
SMOKE_SWEEP = ((10, 200.0),)


def run_once(n_models: int, total_rate: float, *, duration: float = 2.0,
             n_workers: int = 2, gpus_per_worker: int = 4,
             seed: int = 0) -> dict:
    models = {f"m{i}": table1_modeldef(f"m{i}",
                                       family=FAMILIES[i % len(FAMILIES)])
              for i in range(n_models)}
    cl = build_cluster(models, scheduler=ClockworkScheduler(), seed=seed,
                       preload=[f"m{i}" for i in range(n_models // 2)],
                       n_workers=n_workers, gpus_per_worker=gpus_per_worker)
    rate = total_rate / n_models
    clients = [OpenLoopClient(cl.loop, cl.submit, mid, 0.100, rate=rate,
                              stop=duration, seed=seed + i)
               for i, mid in enumerate(models)]
    cl.attach_clients(clients)
    t0 = time.perf_counter()
    summary = cl.run(duration)
    wall = time.perf_counter() - t0
    loop_stats = cl.loop.stats()
    ticks = [g.value for g in cl.recorder.iter_gauges(TICK_LATENCY_GAUGE)]
    requests = summary["total"]
    return {
        "n_models": n_models,
        "total_rate_rs": total_rate,
        "sim_seconds": duration,
        "wall_s": wall,
        "requests": requests,
        "requests_per_wall_s": requests / wall if wall > 0 else 0.0,
        "events_per_wall_s": loop_stats["events_per_wall_s"],
        "events_total": loop_stats["events_total"],
        "sim_s_per_wall_s": duration / wall if wall > 0 else 0.0,
        "mean_tick_us": 1e6 * sum(ticks) / max(len(ticks), 1),
        "p99_tick_us": 1e6 * quantile(ticks, 0.99),
        "decisions": {k: summary[k]
                      for k in ("goodput", "timeout", "rejected")},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_simulator.json")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="simulated seconds per point")
    args = ap.parse_args(argv)

    sweep = SMOKE_SWEEP if args.smoke else FULL_SWEEP
    duration = 0.5 if args.smoke else args.duration

    # cold-start warmup, not measured
    run_once(10, 100.0, duration=0.05)

    results = []
    for n, rate in sweep:
        row = run_once(n, rate, duration=duration)
        results.append(row)
        print(f"n={n:5d} rate={rate:7.0f}r/s  "
              f"req/wall-s={row['requests_per_wall_s']:10.0f}  "
              f"events/wall-s={row['events_per_wall_s']:10.0f}  "
              f"sim-s/wall-s={row['sim_s_per_wall_s']:6.2f}  "
              f"tick mean={row['mean_tick_us']:7.1f}us")

    out = {
        "bench": "simulator_throughput",
        "mode": "smoke" if args.smoke else "full",
        "config": {"duration_s": duration, "n_workers": 2,
                   "gpus_per_worker": 4, "slo_s": 0.100},
        "host": {"python": platform.python_version(),
                 "machine": platform.machine()},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


def run(quick: bool = False):
    """benchmarks.run entry point — writes under experiments/bench so the
    committed repo-root baseline is only updated deliberately."""
    import os

    from benchmarks.common import OUT_DIR
    os.makedirs(OUT_DIR, exist_ok=True)
    argv = ["--out", os.path.join(OUT_DIR, "BENCH_simulator.json")]
    if quick:
        argv.append("--smoke")
    main(argv)


if __name__ == "__main__":
    sys.exit(main())
