"""Table 1: per-model LOAD/INFER profiles.

Two parts: (a) real measured profiles of the CPU-served models (reduced
ResNet + LM decode engines) — the live analogue of the paper's profiling
step; (b) the roofline-derived TPU v5e profiles for the assigned LM
architectures (written by benchmarks/roofline.py from dry-run artifacts).
"""
from __future__ import annotations

from benchmarks.common import report_line, write_csv
from repro.serving.engine import make_lm_decode_model, make_resnet_model


def run(quick: bool = False):
    rows = []
    specs = [("resnet_tiny", lambda: make_resnet_model(
        "resnet_tiny", scale=16, img=64, batches=(1, 2, 4)))]
    if not quick:
        specs += [
            ("resnet_small", lambda: make_resnet_model(
                "resnet_small", scale=8, img=64, batches=(1, 2, 4))),
            ("qwen2_decode", lambda: make_lm_decode_model(
                "qwen2_decode", "qwen2-0.5b", batches=(1, 2, 4), ctx=128)),
            ("mamba2_decode", lambda: make_lm_decode_model(
                "mamba2_decode", "mamba2-130m", batches=(1, 2, 4), ctx=128)),
        ]
    for name, mk in specs:
        jm = mk()
        prof = jm.seed_profiles()
        load_ms = prof[("LOAD", name, 1)] * 1e3
        b_ms = {b: prof[("INFER", name, b)] * 1e3
                for b in jm.batches}
        rows.append((name, jm.weights_bytes / 1e6, load_ms,
                     *[b_ms.get(b, float("nan")) for b in (1, 2, 4)]))
        report_line(f"table1_{name}", b_ms[1] * 1e3,
                    f"load_ms={load_ms:.2f};b1_ms={b_ms[1]:.2f}")
    write_csv("table1_model_profiles", rows,
              ["model", "weights_mb", "load_ms", "b1_ms", "b2_ms", "b4_ms"])
    return rows
