"""Control-plane perf benchmark: per-tick scheduler latency vs model count.

Sweeps the number of served models (10 -> 2,000) under an open-loop load on
a multi-GPU simulated cluster and measures the wall-clock latency of every
`ClockworkScheduler.tick()` via the `scheduler.tick_latency_s` telemetry
gauge. With `--compare` (the default for the committed baseline) it also
runs the frozen pre-optimization scheduler
(`repro.core.scheduler_reference.ReferenceClockworkScheduler`) on the same
workload, asserts the two made *identical* decisions (goodput / timeout /
reject counts), and reports the speedup.

Output: BENCH_scheduler.json (see DESIGN.md §4 for how to read/update it).

Usage:
    PYTHONPATH=src python benchmarks/bench_scheduler.py            # full
    PYTHONPATH=src python benchmarks/bench_scheduler.py --smoke    # CI
    ... [--no-compare] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

from repro.core.scheduler import TICK_LATENCY_GAUGE, ClockworkScheduler
from repro.core.scheduler_reference import ReferenceClockworkScheduler
from repro.serving.simulator import PAPER_TABLE1, build_cluster, table1_modeldef
from repro.serving.workload import OpenLoopClient
from repro.telemetry.reports import quantile

FAMILIES = list(PAPER_TABLE1)

# model-count sweep; reference comparison points are a subset because the
# pre-optimization scheduler is painfully slow at scale (that's the point)
FULL_SWEEP = (10, 100, 250, 500, 1000, 2000)
FULL_COMPARE = (10, 100, 1000, 2000)
SMOKE_SWEEP = (10, 50)
SMOKE_COMPARE = (10, 50)


def _timed(cls):
    """Wrap a scheduler class to sample tick() wall latency uniformly for
    both implementations (the optimized one also self-reports via the
    telemetry gauge; the frozen reference predates it)."""
    class Timed(cls):
        def __init__(self, **kw):
            super().__init__(**kw)
            self.tick_samples = []

        def tick(self):
            t0 = time.perf_counter()
            super().tick()
            self.tick_samples.append(time.perf_counter() - t0)

    return Timed


def run_repeated(sched_cls, n_models: int, repeats: int, **kw) -> dict:
    """Median-of-N runs (by mean tick latency) — the simulations are
    deterministic, so repeats differ only by host noise."""
    runs = sorted((run_once(sched_cls, n_models, **kw)
                   for _ in range(repeats)),
                  key=lambda r: r["mean_tick_us"])
    return runs[len(runs) // 2]


def run_once(sched_cls, n_models: int, *, duration: float = 0.5,
             rate_per_model: float = 4.0, n_workers: int = 2,
             gpus_per_worker: int = 4, seed: int = 0) -> dict:
    models = {f"m{i}": table1_modeldef(f"m{i}",
                                       family=FAMILIES[i % len(FAMILIES)])
              for i in range(n_models)}
    sched = _timed(sched_cls)()
    cl = build_cluster(models, scheduler=sched, seed=seed,
                       preload=[f"m{i}" for i in range(n_models // 2)],
                       n_workers=n_workers, gpus_per_worker=gpus_per_worker)
    clients = [OpenLoopClient(cl.loop, cl.submit, mid, 0.100,
                              rate=rate_per_model, stop=duration,
                              seed=seed + i)
               for i, mid in enumerate(models)]
    cl.attach_clients(clients)
    t0 = time.perf_counter()
    summary = cl.run(duration)
    wall = time.perf_counter() - t0
    xs = sched.tick_samples
    return {
        "ticks": len(xs),
        "mean_tick_us": 1e6 * sum(xs) / max(len(xs), 1),
        "p99_tick_us": 1e6 * quantile(xs, 0.99),
        "max_tick_us": 1e6 * max(xs) if xs else 0.0,
        "wall_s": wall,
        "decisions": {k: summary[k]
                      for k in ("goodput", "timeout", "rejected")},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--no-compare", action="store_true",
                    help="skip the pre-optimization reference runs")
    ap.add_argument("--out", default="BENCH_scheduler.json")
    ap.add_argument("--duration", type=float, default=0.5,
                    help="simulated seconds per point")
    ap.add_argument("--repeats", type=int, default=None,
                    help="runs per point, median reported "
                         "(default 3, 1 with --smoke)")
    args = ap.parse_args(argv)
    repeats = args.repeats if args.repeats else (1 if args.smoke else 3)

    sweep = SMOKE_SWEEP if args.smoke else FULL_SWEEP
    compare_at = () if args.no_compare else (
        SMOKE_COMPARE if args.smoke else FULL_COMPARE)

    # warm the interpreter (allocator, bytecode caches) so the first sweep
    # point isn't charged for cold-start effects
    run_once(ClockworkScheduler, 10, duration=0.05)
    run_once(ReferenceClockworkScheduler, 10, duration=0.05)

    results = []
    for n in sweep:
        opt = run_repeated(ClockworkScheduler, n, repeats,
                           duration=args.duration)
        row = {"n_models": n, "optimized": opt}
        if n in compare_at:
            ref = run_repeated(ReferenceClockworkScheduler, n, repeats,
                               duration=args.duration)
            row["reference"] = ref
            row["speedup_mean_tick"] = (
                ref["mean_tick_us"] / opt["mean_tick_us"]
                if opt["mean_tick_us"] else float("inf"))
            row["decisions_identical"] = (
                opt["decisions"] == ref["decisions"])
        results.append(row)
        extra = ""
        if "reference" in row:
            extra = (f"  ref={row['reference']['mean_tick_us']:8.1f}us"
                     f"  speedup={row['speedup_mean_tick']:5.1f}x"
                     f"  identical={row['decisions_identical']}")
        print(f"n={n:5d}  opt mean={opt['mean_tick_us']:8.1f}us"
              f"  p99={opt['p99_tick_us']:8.1f}us{extra}")

    out = {
        "bench": "scheduler_tick_latency",
        "mode": "smoke" if args.smoke else "full",
        "config": {"duration_s": args.duration, "rate_per_model": 4.0,
                   "n_workers": 2, "gpus_per_worker": 4,
                   "slo_s": 0.100, "repeats_median_of": repeats,
                   "gauge": TICK_LATENCY_GAUGE},
        "host": {"python": platform.python_version(),
                 "machine": platform.machine()},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")

    bad = [r for r in results if not r.get("decisions_identical", True)]
    return 1 if bad else 0


def run(quick: bool = False):
    """benchmarks.run entry point — writes under experiments/bench so the
    committed repo-root baseline is only updated deliberately."""
    import os

    from benchmarks.common import OUT_DIR
    os.makedirs(OUT_DIR, exist_ok=True)
    argv = ["--out", os.path.join(OUT_DIR, "BENCH_scheduler.json")]
    if quick:
        argv.append("--smoke")
    main(argv)


if __name__ == "__main__":
    sys.exit(main())
