"""§Roofline: compute/memory/collective terms per (arch x shape x mesh)
from the dry-run's compiled artifacts (launch/dryrun.py JSON output).

Terms (TPU v5e constants from the assignment):
  compute_s    = HLO_FLOPs_per_device / 197e12
  memory_s     = HLO_bytes_per_device / 819e9
  collective_s = collective_operand_bytes_per_device / 50e9

cost_analysis() on the SPMD-partitioned module reports *per-device* numbers,
so dividing by per-chip peaks gives per-chip seconds directly (equivalent to
the global/(chips x peak) form in the spec). `bytes accessed` counts operand
+ result bytes per HLO op — an upper bound on HBM traffic (fusion reuse not
modeled), so the memory term is conservative.

Also emits v5e serving profiles (PREFILL/DECODE/LOAD estimates per arch) that
parameterize the serving simulator — closing the loop between the dry-run
and the Clockwork experiments.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import report_line, write_csv
from repro.utils import V5E

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def _n_params_and_active(arch: str):
    from repro.configs import get_config
    from repro.models import params as pspec
    from repro.models.registry import get_bundle
    cfg = get_config(arch)
    spec = get_bundle(cfg).spec()
    n = pspec.param_count(spec)
    if cfg.moe is None:
        return n, n
    # active = non-expert params + top_k/num_experts of expert params
    moe_leaves = 0
    def count_moe(tree, inside):
        nonlocal moe_leaves
        if isinstance(tree, dict):
            for k, v in tree.items():
                count_moe(v, inside or k == "moe")
        elif isinstance(tree, (list, tuple)):
            for v in tree:
                count_moe(v, inside)
        elif inside and hasattr(tree, "shape"):
            import numpy as np
            moe_leaves += int(np.prod(tree.shape))
    count_moe(spec, False)
    # exclude the (replicated) router from the expert fraction
    active = (n - moe_leaves) + moe_leaves * cfg.moe.top_k / cfg.moe.num_experts
    return n, active


def model_flops(arch: str, shape_name: str) -> float:
    """6·N·D (train) / 2·N·D (inference) convention, N = active params,
    per device on the single-pod mesh (256 chips)."""
    from repro.configs import SHAPES
    shape = SHAPES[shape_name]
    n, n_active = _n_params_and_active(arch)
    tokens = {"train": shape.global_batch * shape.seq_len,
              "prefill": shape.global_batch * shape.seq_len,
              "decode": shape.global_batch}[shape.kind]
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


class FakeMesh:
    """Axis metadata stand-in so sharding math runs without 512 devices."""

    def __init__(self, multi: bool):
        self.axis_names = (("pod", "data", "model") if multi
                           else ("data", "model"))
        sizes = (2, 16, 16) if multi else (16, 16)
        self.shape = dict(zip(self.axis_names, sizes))


def _local_bytes(spec_tree, rules, mesh) -> int:
    import numpy as np
    from jax import numpy as jnp
    from repro.distributed.sharding import spec_for, use_rules
    from repro.models import params as pspec
    total = 0
    with use_rules(mesh, rules):
        for s in pspec._spec_leaves(spec_tree):
            p = spec_for(rules, s.axes, tuple(s.shape))
            nsh = 1
            for e in p:
                for a in (e if isinstance(e, tuple) else (e,)):
                    if a is not None:
                        nsh *= mesh.shape[a]
            total += (int(np.prod(s.shape))
                      * jnp.dtype(s.dtype).itemsize) // max(nsh, 1)
    return total


def analytic_memory_bytes(arch: str, shape_name: str, multi: bool) -> float:
    """Per-device HBM traffic per step on the PRODUCTION path (Pallas
    kernels stream attention blocks through VMEM; weights/state read once
    per pass). The parsed HLO number is the XLA-fallback upper bound."""
    from repro.configs import SHAPES, get_config
    from repro.distributed.sharding import make_rules
    from repro.models.registry import get_bundle
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = FakeMesh(multi)
    rules = make_rules(mesh, cfg, shape.kind, shape)
    bundle = get_bundle(cfg)
    params_local = _local_bytes(bundle.spec(), rules, mesh)

    dp = 1
    for a in rules.get("batch", ()):
        dp *= mesh.shape[a]
    tokens_local = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1) // dp
    d = cfg.d_model
    L = cfg.num_layers + (cfg.enc_layers if cfg.is_encdec else 0)
    v_local = cfg.vocab_padded // mesh.shape.get("model", 1)

    if shape.kind == "train":
        n_mb = max(1, min(cfg.microbatches, shape.global_batch // dp))
        b_mb_tok = tokens_local // n_mb
        groups = max(1, cfg.num_layers // max(len(cfg.pattern), 1))
        seq_div = mesh.shape.get("model", 1) if cfg.seq_shard_train else 1
        carry = groups * b_mb_tok * d * 2 // seq_div
        weights = 3 * n_mb * params_local       # fwd + remat + bwd reads
        update = 4 * params_local               # grads + param update + opt
        acts = 4 * L * b_mb_tok * d * 2 * n_mb  # stream in/out per block
        logits = 3 * b_mb_tok * v_local * 4 * n_mb
        return weights + update + 2 * carry * n_mb + acts + logits
    if shape.kind == "prefill":
        cross = shape.seq_len if cfg.is_encdec else 0
        cache_local = _local_bytes_cache(bundle, cfg, shape, mesh, rules,
                                         cross)
        acts = 4 * L * tokens_local * d * 2
        return params_local + acts + cache_local + tokens_local * v_local // max(shape.seq_len, 1) * 4
    # decode: read weights (MoE: only routed share) + stream the cache
    from repro.configs.shapes import decode_cache_len
    self_len, cross = decode_cache_len(cfg, shape)
    cache_local = _local_bytes_cache(bundle, cfg, shape, mesh, rules, cross,
                                     self_len)
    w = params_local
    if cfg.moe is not None:
        b_local = max(1, shape.global_batch // dp)
        touched = min(1.0, b_local * cfg.moe.top_k / cfg.moe.num_experts
                      * mesh.shape.get("data", 1))
        # expert weights dominate; scale by the touched fraction
        w = params_local * (0.15 + 0.85 * touched)
    return w + cache_local + 4 * L * tokens_local * d * 2


def _local_bytes_cache(bundle, cfg, shape, mesh, rules, cross, self_len=None):
    from repro.configs.shapes import decode_cache_len
    if self_len is None:
        self_len, cross = decode_cache_len(cfg, shape)
    cache_abs = bundle.cache_abstract(shape.global_batch, self_len, cross)
    axes = bundle.cache_axes(cross)
    import numpy as np
    from jax import numpy as jnp, tree as jtree
    from repro.distributed.sharding import spec_for, use_rules
    flat, treedef = jtree.flatten(cache_abs)
    ax_flat = treedef.flatten_up_to(axes)
    total = 0
    with use_rules(mesh, rules):
        for sds, ax in zip(flat, ax_flat):
            p = spec_for(rules, ax, tuple(sds.shape))
            nsh = 1
            for e in p:
                for a in (e if isinstance(e, tuple) else (e,)):
                    if a is not None:
                        nsh *= mesh.shape[a]
            total += (int(np.prod(sds.shape))
                      * jnp.dtype(sds.dtype).itemsize) // max(nsh, 1)
    return total


def analyze(dryrun_dir: str = DRYRUN_DIR, mesh: str = "single"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir,
                                           f"*__{mesh}.json"))):
        d = json.load(open(f))
        if d.get("status") != "ok":
            continue
        devices = d["devices"]
        lp = d.get("looped")
        if lp:   # loop-nest-corrected totals (hloparse)
            comp = lp["flops"] / V5E.peak_bf16_flops
            memb = lp["hbm_bytes"] / V5E.hbm_bandwidth
            coll = lp["coll_operand_bytes"] / V5E.ici_link_bandwidth
            coll_wire = lp["coll_wire_bytes"] / V5E.ici_link_bandwidth
        else:
            comp = d["cost"]["flops"] / V5E.peak_bf16_flops
            memb = d["cost"]["bytes_accessed"] / V5E.hbm_bandwidth
            coll = d["collective_operand_bytes"] / V5E.ici_link_bandwidth
            coll_wire = d["collective_wire_bytes"] / V5E.ici_link_bandwidth
        try:
            mem_k = analytic_memory_bytes(d["arch"], d["shape"],
                                          mesh == "multi"
                                          ) / V5E.hbm_bandwidth
        except Exception:
            mem_k = memb
        # production terms: Pallas-kernel memory path + wire-model collectives
        terms = {"compute": comp, "memory": mem_k, "collective": coll_wire}
        dom = max(terms, key=terms.get)
        mf = model_flops(d["arch"], d["shape"]) / devices
        hlo_flops = lp["flops"] if lp else d["cost"]["flops"]
        ratio = mf / max(hlo_flops, 1.0)
        step_s = max(terms.values())
        frac = comp / max(step_s, 1e-12)       # compute-roofline fraction
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": mesh,
            "mode": d.get("mode"),
            "compute_s": comp, "memory_s": mem_k,
            "memory_xla_fallback_s": memb, "collective_s": coll_wire,
            "collective_operand_s": coll,
            "dominant": dom, "step_s_bound": step_s,
            "model_flops_ratio": ratio,
            "roofline_fraction": frac,
            "peak_gib": d["memory"]["peak_per_device"] / 2**30,
            "peak_tpu_gib": max(d["memory"].get("peak_tpu_estimate", 0),
                                0) / 2**30,
        })
    return rows


def suggestion(r) -> str:
    if r["dominant"] == "collective":
        return ("overlap/shrink collectives: reorder sharding to cut "
                "all-gathers, compress grads, or fuse the psum pair")
    if r["dominant"] == "memory":
        if r["shape"].startswith("decode") or r["shape"] == "long_500k":
            return ("decode is KV-bandwidth-bound by nature: shrink the "
                    "cache (int8 KV, windowed layers) or raise batch")
        return ("reduce HBM traffic: larger fusion blocks, bf16 scores, "
                "avoid materializing intermediates")
    if r["model_flops_ratio"] < 0.5:
        return ("compute-bound with low useful-FLOP ratio: cut remat "
                "recompute or masked/causal waste in attention")
    return "near compute roofline: raise arithmetic intensity or accept"


def emit_v5e_profiles(rows, out="experiments/v5e_profiles.json"):
    """Serving latency profiles for the simulator: step-time bounds per arch
    (batch scaling linearized from the decode/prefill cells)."""
    prof = {}
    for r in rows:
        if r["mesh"] != "single":
            continue
        a = prof.setdefault(r["arch"], {})
        a[r["shape"]] = {"step_s": r["step_s_bound"],
                         "dominant": r["dominant"]}
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(prof, f, indent=1)
    return out


def run(quick: bool = False):
    all_rows = []
    for mesh in ("single", "multi"):
        all_rows += analyze(mesh=mesh)
    if not all_rows:
        report_line("roofline", 0.0, "no dryrun artifacts found")
        return []
    csv_rows = [(r["arch"], r["shape"], r["mesh"], r["mode"],
                 f"{r['compute_s']:.4e}", f"{r['memory_s']:.4e}",
                 f"{r['collective_s']:.4e}", r["dominant"],
                 f"{r['model_flops_ratio']:.3f}",
                 f"{r['roofline_fraction']:.3f}",
                 f"{r['peak_gib']:.2f}", f"{r['peak_tpu_gib']:.2f}",
                 suggestion(r))
                for r in all_rows]
    write_csv("roofline", csv_rows,
              ["arch", "shape", "mesh", "mode", "compute_s", "memory_s",
               "collective_s", "dominant", "model_flops_ratio",
               "roofline_fraction", "peak_gib", "peak_tpu_gib",
               "suggestion"])
    emit_v5e_profiles(all_rows)
    singles = [r for r in all_rows if r["mesh"] == "single"]
    by_dom = {}
    for r in singles:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    mean_frac = sum(r["roofline_fraction"] for r in singles) / len(singles)
    report_line("roofline_summary", 0.0,
                f"cells={len(singles)};dominant={by_dom};"
                f"mean_compute_fraction={mean_frac:.3f}")
    return all_rows
