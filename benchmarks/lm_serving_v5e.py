"""Clockwork-for-LLMs on TPU v5e profiles: serve the assigned architectures.

Closes the dry-run -> serving loop: DECODE/PREFILL step-time bounds derived
from the compiled dry-run artifacts (`experiments/v5e_profiles.json`,
written by benchmarks/roofline.py) become the latency models of pod-slice
workers, and the *same* Clockwork controller that served ResNets schedules
continuous-batching DECODE actions across architectures with per-arch SLOs.

Worker = one 256-chip v5e pod slice hosting every model (weights in host
RAM, paged HBM residency — the paper's architecture at pod scale). LOAD =
host->HBM DMA across the pod's 64 hosts.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import report_line, write_csv
from repro.core.actions import ActionType
from repro.core.scheduler import ClockworkScheduler
from repro.core.worker import ModelDef
from repro.serving.simulator import build_cluster
from repro.serving.workload import OpenLoopClient

PROFILE_PATH = os.environ.get("V5E_PROFILES", "experiments/v5e_profiles.json")
HOST_DMA_PER_POD = 25e9 * 64      # 64 hosts per v5e-256 pod, parallel DMA

# serve the architectures with O(1)-or-small decode state first (the most
# Clockwork-friendly), plus one big dense model
SERVE_ARCHS = ["mamba2-130m", "recurrentgemma-2b", "qwen2-0.5b",
               "gemma2-27b", "starcoder2-3b"]


def _weights_bytes(arch: str) -> int:
    from repro.configs import get_config
    from repro.models import params as pspec
    from repro.models.registry import get_bundle
    return pspec.param_bytes(get_bundle(get_config(arch)).spec())


def v5e_modeldefs():
    if not os.path.exists(PROFILE_PATH):
        return None
    prof = json.load(open(PROFILE_PATH))
    models = {}
    for arch in SERVE_ARCHS:
        p = prof.get(arch, {})
        dec = p.get("decode_32k", {}).get("step_s")
        if dec is None:
            continue
        # decode step time vs batch: memory-bound floor (weights read) +
        # batch-proportional KV stream, anchored at the batch-128 dry-run cell
        lat = {}
        for b in (1, 2, 4, 8, 16, 32, 64, 128):
            lat[("DECODE", b)] = max(dec * (0.3 + 0.7 * b / 128), 1e-5)
        models[arch] = ModelDef(
            model_id=arch,
            weights_bytes=_weights_bytes(arch),
            exec_latency=lat)
    return models


def run(quick: bool = False):
    models = v5e_modeldefs()
    if not models:
        report_line("lm_serving_v5e", 0.0, "no v5e profiles (run dry-run)")
        return None
    dur = 8.0 if quick else 20.0
    # 4 pod-slice workers; HBM pool ~16GB*256 minus workspace
    cl = build_cluster(models, n_workers=4, device_memory=256 * 14e9,
                       host_to_dev_bw=HOST_DMA_PER_POD,
                       scheduler=ClockworkScheduler(
                           batch_sizes=(1, 2, 4, 8, 16, 32, 64, 128),
                           action_type=ActionType.DECODE))
    # per-arch SLO: small models get tight decode SLOs, big ones looser
    slos = {"mamba2-130m": 0.005, "qwen2-0.5b": 0.010,
            "recurrentgemma-2b": 0.010, "starcoder2-3b": 0.020,
            "gemma2-27b": 0.040}
    rates = {"mamba2-130m": 4000.0, "qwen2-0.5b": 2500.0,
             "recurrentgemma-2b": 2000.0, "starcoder2-3b": 1500.0,
             "gemma2-27b": 800.0}
    clients = [OpenLoopClient(cl.loop, cl.submit, mid, slos[mid],
                              rate=rates[mid] * (0.3 if quick else 1.0),
                              stop=dur, seed=i)
               for i, mid in enumerate(models)]
    cl.attach_clients(clients)
    s = cl.run(dur + 0.5)

    rows = []
    for mid in models:
        done = [r for r in cl.controller.completed if r.model_id == mid]
        ok = sum(1 for r in done if r.status == "ok")
        rows.append((mid, slos[mid] * 1e3, len(done), ok,
                     ok / max(len(done), 1)))
    write_csv("lm_serving_v5e", rows,
              ["arch", "slo_ms", "requests", "ok", "satisfaction"])
    total = max(1, s["goodput"] + s["timeout"] + s["rejected"])
    report_line("lm_serving_v5e", 0.0,
                f"archs={len(models)};goodput={s['goodput'] / dur:.0f}r/s;"
                f"sat={s['goodput'] / total:.4f};timeouts={s['timeout']};"
                f"p99_ms={(s['p99'] or 0) * 1e3:.1f}")
    return s
