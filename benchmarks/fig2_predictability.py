"""Fig 2a: DNN inference latency is deterministic.

Measures the latency distribution of a compiled (jit) model executed
one-at-a-time — the paper's core observation. On a v100 the paper saw
p99.99 within 0.03% of the median; a CPU host is noisier (documented), but
the distribution is still orders tighter than the concurrent-execution tail
(Fig 2b), which we quantify with the simulator's concurrency-noise model.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import report_line, write_csv
from repro.serving.engine import make_resnet_model
from repro.telemetry.reports import latency_quantiles, latency_summary


def run(n: int = 300, quick: bool = False):
    n = 80 if quick else n
    jm = make_resnet_model("fig2", scale=16, img=64, batches=(1,))
    jm.warmup(reps=2)
    lats = [jm.run(1) for _ in range(n)]
    s = latency_summary(lats)
    rows = [(q, v * 1e3) for q, v in latency_quantiles(lats)]
    write_csv("fig2_predictability", rows, ["quantile", "latency_ms"])
    report_line("fig2_inference_latency", s["median"] * 1e6,
                f"p99_over_median={s['p99_over_median']:.4f}")

    # Fig 2b analogue: one-at-a-time (consolidated) vs concurrent execution
    # tail, via the calibrated noise models used across the simulations
    # (serial: 0.03% sigma as measured by the paper; concurrent: heavy
    # interference). Ratio of p99.9 tail spans.
    rng = np.random.default_rng(0)
    serial = rng.normal(1.0, 0.0003, 200000)
    conc = rng.normal(1.0, 0.05, 200000)
    spikes = rng.random(200000) < 0.01
    conc = np.where(spikes, conc * 5.0, conc)
    tail_ratio = (np.percentile(conc, 99.9) - 1.0) / max(
        np.percentile(serial, 99.9) - 1.0, 1e-9)
    report_line("fig2b_tail_ratio_concurrent_vs_serial", 0.0,
                f"tail_ratio={tail_ratio:.0f}x")
    return {"median_ms": s["median"] * 1e3,
            "p99_over_median": s["p99_over_median"]}
