"""Fig 8: MAF-like trace replay — many models, mixed sustained/bursty/
periodic/cold workloads (the paper replays the Microsoft Azure Functions
trace; we synthesize the same workload-shape mix, DESIGN.md §6)."""
from __future__ import annotations

from benchmarks.common import report_line, write_csv
from repro.core.scheduler import ClockworkScheduler
from repro.serving.simulator import TimeSeries, build_cluster, table1_modeldef
from repro.serving.workload import VariableRateClient, maf_like_rates

FAMILIES = ["resnet50_v2", "resnet18_v2", "densenet121", "googlenet",
            "inceptionv3", "resnext50_32x4d", "winograd_resnet18_v2",
            "mobile_pose_mobilenet1.0"]


def run(quick: bool = False):
    n_models = 40 if quick else 120
    total_rate = 400.0 if quick else 1200.0
    dur = 30.0 if quick else 90.0
    n_workers = 2 if quick else 4
    rates = maf_like_rates(n_models, total_rate, dur, seed=2)
    models = {mid: table1_modeldef(mid, family=FAMILIES[i % len(FAMILIES)])
              for i, mid in enumerate(rates)}
    cl = build_cluster(models, n_workers=n_workers, device_memory=16e9,
                       scheduler=ClockworkScheduler())
    clients = [VariableRateClient(cl.loop, cl.submit, mid, 0.100, fn,
                                  stop=dur, seed=i,
                                  max_rate=total_rate / 4)
               for i, (mid, fn) in enumerate(rates.items())]
    cl.attach_clients(clients)
    ts = TimeSeries(cl, dt=max(dur / 30, 1.0))
    s = cl.run(dur + 0.5)

    cold = sum(1 for r in cl.controller.results_log
               if r.action_type.value == "LOAD"
               and r.status.value == "SUCCESS")
    total = max(1, s["goodput"] + s["timeout"] + s["rejected"])
    rows = [(x["t"], x["goodput_rs"], (x["p99"] or 0) * 1e3,
             (x["max"] or 0) * 1e3) for x in ts.samples]
    write_csv("fig8_maf_trace", rows, ["t", "goodput_rs", "p99_ms",
                                       "max_ms"])
    report_line("fig8_maf_trace", 0.0,
                f"models={n_models};rate={s['goodput'] / dur:.0f}r/s;"
                f"goodput_frac={s['goodput'] / total:.5f};"
                f"timeouts={s['timeout']};loads={cold};"
                f"p999_ms={(s['p999'] or 0) * 1e3:.1f}")
    return s
