"""Fig 5: goodput and tail latency vs SLO — Clockwork vs Clipper-like vs
INFaaS-like, 15 ResNet50 instances x 16 closed-loop clients on one worker."""
from __future__ import annotations

from benchmarks.common import report_line, write_csv
from repro.core.baselines import ClipperScheduler, InfaasScheduler
from repro.core.scheduler import ClockworkScheduler
from repro.serving.simulator import build_cluster, table1_modeldef
from repro.serving.workload import ClosedLoopClient

SCHEDULERS = {
    "clockwork": ClockworkScheduler,
    "clipper_like": ClipperScheduler,
    "infaas_like": InfaasScheduler,
}


def _one(sched_cls, slo: float, dur: float, n_models: int, conc: int,
         concurrent_noise: bool):
    models = {f"resnet50_{i}": table1_modeldef(f"resnet50_{i}")
              for i in range(n_models)}
    # baselines run execution engines they don't control (C2): concurrent
    # streams -> latency variance (paper Fig 2b); Clockwork executes
    # one-at-a-time -> near-deterministic
    noise, spike = ((0.05, 0.01) if concurrent_noise else (0.0003, 0.0))
    cl = build_cluster(models, scheduler=sched_cls(), noise=noise,
                       spike_prob=spike)
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, slo,
                                concurrency=conc) for mid in models]
    cl.attach_clients(clients)
    s = cl.run(dur)
    return s


def run(quick: bool = False):
    dur = 8.0 if quick else 20.0
    n_models, conc = (8, 8) if quick else (15, 16)
    slos = [0.010, 0.025, 0.050, 0.100, 0.250, 0.500]
    rows = []
    for name, cls in SCHEDULERS.items():
        for slo in slos:
            s = _one(cls, slo, dur, n_models, conc,
                     concurrent_noise=(name != "clockwork"))
            rows.append((name, slo * 1e3, s["goodput"] / dur,
                         s["timeout"], s["rejected"],
                         (s["p99"] or 0) * 1e3, (s["max"] or 0) * 1e3))
    write_csv("fig5_goodput_vs_slo", rows,
              ["scheduler", "slo_ms", "goodput_rs", "timeouts", "rejected",
               "p99_ms", "max_ms"])
    cw100 = next(r for r in rows if r[0] == "clockwork" and r[1] == 100.0)
    cl100 = next(r for r in rows if r[0] == "clipper_like" and r[1] == 100.0)
    report_line("fig5_goodput_at_100ms_clockwork", 0.0,
                f"goodput={cw100[2]:.0f}r/s;p99={cw100[5]:.1f}ms;"
                f"timeouts={cw100[3]}")
    report_line("fig5_goodput_at_100ms_clipper", 0.0,
                f"goodput={cl100[2]:.0f}r/s;timeouts={cl100[3]}")
    return rows
