"""Shared helpers for the figure benchmarks."""
from __future__ import annotations

import csv
import os
import time

import numpy as np

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")


def write_csv(name: str, rows, header):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name + ".csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def report_line(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")


def pctile(xs, q):
    from repro.telemetry.reports import quantile
    return quantile(xs, q)
