"""End-to-end behaviour tests for the whole system: a real (non-simulated)
serving round-trip on CPU through the Clockwork controller with a JAX
backend, plus dry-run machinery checks on a small forced-device mesh."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clock import EventLoop, RealClock
from repro.core.controller import Controller
from repro.core.scheduler import ClockworkScheduler
from repro.core.actions import Request
from repro.serving.engine import JaxModel, JaxBackend, make_resnet_model
from repro.core.worker import Worker


def test_real_jax_serving_roundtrip():
    """Controller + worker + actual jit'd ResNet execution on CPU: requests
    go in, on-time responses come out, measured latencies feed the profiler.
    """
    loop = EventLoop(RealClock())
    jm = make_resnet_model("resnet_tiny", scale=16, batches=(1, 2, 4))
    models = {"resnet_tiny": jm.modeldef()}
    backend = JaxBackend({"resnet_tiny": jm})
    w = Worker("w0", loop, backend, models, n_gpus=1)
    controller = Controller(loop, models, ClockworkScheduler(),
                            action_delay=1e-4)
    controller.add_worker(w, profiles=jm.seed_profiles())
    done = []
    controller.on_response = done.append
    t0 = loop.now()
    for i in range(12):
        controller.on_request(Request(model_id="resnet_tiny",
                                      arrival=loop.now(), slo=5.0))
        loop.run_until(loop.now() + 0.02)
    loop.run_until(t0 + 20.0 if False else loop.now() + 3.0)
    ok = [r for r in done if r.status == "ok"]
    assert len(ok) >= 10, [r.status for r in done]
    # profiler learned real executions
    est = controller.profiler.estimate("INFER", "resnet_tiny", 1)
    assert est is not None and est > 0


def test_dryrun_cell_machinery_small_mesh():
    """Run the dry-run driver end-to-end in a subprocess with 8 forced host
    devices and a (2,4) mesh — validates the lowering/analysis pipeline
    without the cost of the 512-device production mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeSpec
        from repro.distributed.steps import build_sharded_step
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import parse_collectives
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config("gemma2-27b")
        shape = ShapeSpec("t", "train", 64, 8)
        step = build_sharded_step(cfg, mesh, shape, chunk=32)
        compiled = step.jitted.lower(*step.abstract).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        colls = parse_collectives(compiled.as_text())
        print(json.dumps({
            "flops": cost.get("flops", 0.0),
            "temp": mem.temp_size_in_bytes,
            "n_collectives": len(colls),
            "kinds": sorted({c["kind"] for c in colls}),
        }))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=__import__("os").path.join(
                             __import__("os").path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    import json
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flops"] > 0
    assert res["n_collectives"] > 0          # sharded training communicates
    assert "all-reduce" in res["kinds"]


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
      %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%dot), replica_groups=[16,16]<=[256], to_apply=%add
      %ag = bf16[8,512]{1,0} all-gather(%x), replica_groups=[32,8]<=[256], dimensions={1}
      %rs = (f32[4,4]{1,0}) reduce-scatter(%y), replica_groups=[1,4]<=[4]
    """
    ops = parse_collectives(hlo)
    kinds = {o["kind"] for o in ops}
    assert kinds == {"all-reduce", "all-gather", "reduce-scatter"}
    ar = next(o for o in ops if o["kind"] == "all-reduce")
    assert ar["result_bytes"] == 16 * 1024 * 4
    assert ar["group"] == 16
    ag = next(o for o in ops if o["kind"] == "all-gather")
    assert ag["operand_bytes"] == 8 * 512 * 2 // 8
