"""End-to-end serving behaviour: Clockwork vs baselines, isolation, cold
starts, predictability (system-level integration tests)."""
import pytest

from repro.core.baselines import ClipperScheduler, InfaasScheduler
from repro.core.scheduler import ClockworkScheduler
from repro.serving.simulator import TimeSeries, build_cluster, table1_modeldef
from repro.serving.workload import (ClosedLoopClient, OpenLoopClient,
                                    VariableRateClient, maf_like_rates)


def _fig5_run(sched_cls, slo, dur=10.0, n_models=8, conc=8):
    models = {f"m{i}": table1_modeldef(f"m{i}") for i in range(n_models)}
    cl = build_cluster(models, scheduler=sched_cls())
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, slo,
                                concurrency=conc) for mid in models]
    cl.attach_clients(clients)
    return cl.run(dur), cl


def test_clockwork_beats_baselines_at_tight_slo():
    s_cw, _ = _fig5_run(ClockworkScheduler, 0.025)
    s_cl, _ = _fig5_run(ClipperScheduler, 0.025)
    s_in, _ = _fig5_run(InfaasScheduler, 0.025)
    # Clockwork: zero timeouts (never responds late)
    assert s_cw["timeout"] == 0
    assert s_cw["goodput"] > 0
    # baselines either time out requests or underperform
    assert s_cl["timeout"] + s_in["timeout"] > 0 or \
        s_cw["goodput"] >= 0.8 * max(s_cl["goodput"], s_in["goodput"])


def test_clockwork_tail_latency_within_slo_under_overload():
    s, cl = _fig5_run(ClockworkScheduler, 0.100, n_models=10, conc=16)
    assert s["timeout"] == 0
    assert s["p99"] <= 0.100 + 1e-6


def test_cold_start_scale_up_shifts_bottleneck():
    """Fig-6 miniature: more active models than fit in device memory —
    the system keeps serving via LOAD/UNLOAD churn (PCIe-bound regime)."""
    n = 60
    models = {f"m{i}": table1_modeldef(f"m{i}") for i in range(n)}
    # small device memory: only ~20 models fit (102.2MB each -> 7 pages)
    cl = build_cluster(models, device_memory=2.2e9,
                       scheduler=ClockworkScheduler())
    clients = [OpenLoopClient(cl.loop, cl.submit, mid, 0.200, rate=8.0,
                              stop=6.0, seed=i)
               for i, mid in enumerate(models)]
    cl.attach_clients(clients)
    s = cl.run(7.0)
    assert s["goodput"] > 0
    loads = [r for r in cl.controller.results_log
             if r.action_type.value == "LOAD" and
             r.status.value == "SUCCESS"]
    # eviction churn: more loads than fit simultaneously
    assert len(loads) > 25
    assert s["timeout"] == 0


def test_isolation_ls_vs_batch_clients():
    """Fig-7-right miniature: latency-sensitive clients keep their goodput
    when saturating batch clients share the cluster."""
    models = {f"ls{i}": table1_modeldef(f"ls{i}") for i in range(2)}
    models.update({f"bc{i}": table1_modeldef(f"bc{i}") for i in range(4)})

    def run(with_bc):
        cl = build_cluster(models, n_workers=2,
                           scheduler=ClockworkScheduler())
        ls = [OpenLoopClient(cl.loop, cl.submit, f"ls{i}", 0.050,
                             rate=100.0, stop=5.0, seed=i)
              for i in range(2)]
        clients = list(ls)
        if with_bc:
            clients += [ClosedLoopClient(cl.loop, cl.submit, f"bc{i}", 10.0,
                                         concurrency=16) for i in range(4)]
        cl.attach_clients(clients)
        cl.run(5.0)
        ls_ok = sum(1 for r in cl.controller.completed
                    if r.model_id.startswith("ls") and r.status == "ok")
        ls_all = sum(1 for r in cl.controller.completed
                     if r.model_id.startswith("ls"))
        bc_ok = sum(1 for r in cl.controller.completed
                    if r.model_id.startswith("bc") and r.status == "ok")
        return ls_ok / max(ls_all, 1), bc_ok

    sat_alone, _ = run(False)
    sat_shared, bc_goodput = run(True)
    assert sat_shared > 0.85 * sat_alone     # LS isolation holds
    assert bc_goodput > 0                    # BC still make progress


def test_maf_like_trace_replay_meets_slo():
    rates = maf_like_rates(30, total_rate=400.0, duration=6.0, seed=1)
    models = {mid: table1_modeldef(mid) for mid in rates}
    cl = build_cluster(models, n_workers=2, scheduler=ClockworkScheduler())
    clients = [VariableRateClient(cl.loop, cl.submit, mid, 0.100, fn,
                                  stop=6.0, seed=i, max_rate=500.0)
               for i, (mid, fn) in enumerate(rates.items())]
    cl.attach_clients(clients)
    ts = TimeSeries(cl, dt=1.0)
    s = cl.run(7.0)
    assert s["timeout"] == 0
    assert s["goodput"] > 0
    assert len(ts.samples) >= 6


def test_prediction_errors_are_small():
    models = {"m0": table1_modeldef("m0")}
    cl = build_cluster(models, scheduler=ClockworkScheduler(), noise=0.0003)
    client = ClosedLoopClient(cl.loop, cl.submit, "m0", 0.100, concurrency=8)
    cl.attach_clients([client])
    cl.run(5.0)
    prof = cl.controller.profiler
    errs = sorted(prof.over_errors + prof.under_errors)
    assert errs, "no predictions recorded"
    p99 = errs[int(0.99 * (len(errs) - 1))]
    assert p99 < 0.002  # paper Fig 9: ~250us at v100 scale
