"""Unit + property tests for the Clockwork core (scheduler invariants)."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.actions import Action, ActionType, Request, ResultStatus
from repro.core.clock import EventLoop, VirtualClock
from repro.core.pagecache import PageCache
from repro.core.predictor import ActionProfiler
from repro.core.scheduler import ClockworkScheduler
from repro.core.worker import ModelDef, SimBackend, Worker
from repro.serving.simulator import build_cluster, table1_modeldef
from repro.serving.workload import ClosedLoopClient, OpenLoopClient


# ------------------------------------------------------------- PageCache

@given(st.lists(st.tuples(st.integers(1, 50), st.booleans()), min_size=1,
                max_size=60))
@settings(max_examples=100, deadline=None)
def test_pagecache_accounting_invariant(ops):
    """free + sum(resident) == total, always; alloc never over-commits."""
    pc = PageCache(64 * pc_page(), pc_page())
    live = {}
    for i, (pages, do_free) in enumerate(ops):
        mid = f"m{i % 7}"
        if do_free and mid in live:
            pc.free(mid)
            live.pop(mid)
        elif mid not in live:
            ok = pc.alloc(mid, pages)
            assert ok == (pages <= 64 - sum(live.values()))
            if ok:
                live[mid] = pages
        assert pc.free_pages == pc.total_pages - sum(live.values())
        assert pc.free_pages >= 0
        assert set(pc.resident) == set(live)


def pc_page():
    return 16 * 1024 * 1024


def test_pagecache_lru_order():
    pc = PageCache(10 * pc_page(), pc_page())
    for m in ("a", "b", "c"):
        pc.alloc(m, 2)
    pc.touch("a")
    assert pc.lru_candidate() == "b"
    assert pc.lru_candidate(exclude=("b",)) == "c"


# ------------------------------------------------------------- predictor

def test_profiler_rolling_max_prediction():
    p = ActionProfiler(window=5)
    p.seed("INFER", "m", 1, 0.010)
    assert p.estimate("INFER", "m", 1) == pytest.approx(0.010)
    for d in (0.002, 0.003, 0.001):
        p.observe("INFER", "m", 1, d)
    assert p.estimate("INFER", "m", 1) == pytest.approx(0.003)
    # window slides: old max falls out
    for d in (0.001,) * 5:
        p.observe("INFER", "m", 1, d)
    assert p.estimate("INFER", "m", 1) == pytest.approx(0.001)
    # over/under errors recorded
    assert len(p.over_errors) + len(p.under_errors) == 8


# ------------------------------------------------------------- worker

def _one_worker_loop():
    loop = EventLoop(VirtualClock())
    models = {"m": ModelDef("m", int(100e6),
                            {("INFER", 1): 0.003, ("INFER", 2): 0.004})}
    w = Worker("w0", loop, SimBackend(noise=0.0), models, n_gpus=1)
    results = []
    w.on_result = results.append
    return loop, w, results


def test_worker_rejects_late_actions():
    loop, w, results = _one_worker_loop()
    w.pagecaches[0].alloc("m", 7)
    # latest already passed at delivery
    a = Action(type=ActionType.INFER, model_id="m", worker_id="w0", gpu_id=0,
               earliest=0.0, latest=-1.0, expected_duration=0.003)
    w.receive(a)
    loop.run_until(1.0)
    assert results[0].status is ResultStatus.REJECTED_LATE


def test_worker_waits_for_earliest():
    loop, w, results = _one_worker_loop()
    w.pagecaches[0].alloc("m", 7)
    a = Action(type=ActionType.INFER, model_id="m", worker_id="w0", gpu_id=0,
               earliest=0.5, latest=1.0, expected_duration=0.003)
    w.receive(a)
    loop.run_until(2.0)
    assert results[0].status is ResultStatus.SUCCESS
    assert results[0].t_start >= 0.5


def test_worker_infer_requires_residency():
    loop, w, results = _one_worker_loop()
    a = Action(type=ActionType.INFER, model_id="m", worker_id="w0", gpu_id=0,
               earliest=0.0, latest=1.0, expected_duration=0.003)
    w.receive(a)
    loop.run_until(1.0)
    assert results[0].status is ResultStatus.ERROR_NOT_LOADED


def test_worker_load_then_infer_and_one_at_a_time():
    loop, w, results = _one_worker_loop()
    load = Action(type=ActionType.LOAD, model_id="m", worker_id="w0",
                  gpu_id=0, earliest=0.0, latest=1.0,
                  expected_duration=0.009)
    w.receive(load)
    for _ in range(3):
        w.receive(Action(type=ActionType.INFER, model_id="m",
                         worker_id="w0", gpu_id=0, earliest=0.02,
                         latest=10.0, expected_duration=0.003))
    loop.run_until(5.0)
    ok = [r for r in results if r.status is ResultStatus.SUCCESS]
    assert len(ok) == 4
    infers = [r for r in ok if r.action_type is ActionType.INFER]
    # serial EXEC: no overlap between inference executions
    spans = sorted((r.t_start, r.t_end) for r in infers)
    for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
        assert s2 >= e1 - 1e-9


# --------------------------------------------------- end-to-end invariants

@given(slo_ms=st.sampled_from([10, 25, 50, 100, 250]),
       n_models=st.integers(1, 6), conc=st.integers(1, 8),
       seed=st.integers(0, 5))
@settings(max_examples=12, deadline=None)
def test_clockwork_never_violates_slo_property(slo_ms, n_models, conc, seed):
    """Property (paper's headline): completed requests meet their SLO; the
    only failure mode is *proactive rejection*, never a late response —
    modulo the action-delay margin on external factors (C3)."""
    models = {f"m{i}": table1_modeldef(f"m{i}") for i in range(n_models)}
    cl = build_cluster(models, scheduler=ClockworkScheduler(), seed=seed)
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, slo_ms / 1e3,
                                concurrency=conc) for mid in models]
    cl.attach_clients(clients)
    s = cl.run(3.0)
    assert s["timeout"] <= 0.01 * max(s["goodput"], 1)
    for r in cl.controller.completed:
        if r.status == "ok":
            assert r.completion <= r.deadline + 1e-6


def test_failed_worker_detected_and_traffic_rerouted():
    models = {"m0": table1_modeldef("m0")}
    cl = build_cluster(models, n_workers=2, scheduler=ClockworkScheduler(),
                       preload=["m0", "m0"])
    # preload m0 on both workers' gpu0 (round-robin placed)
    client = ClosedLoopClient(cl.loop, cl.submit, "m0", 0.100, concurrency=8)
    cl.attach_clients([client])
    cl.controller.start_heartbeats()
    cl.loop.schedule(1.0, cl.workers[0].fail)
    s = cl.run(4.0)
    assert cl.controller.stats["dead_workers"] == 1
    assert "w0" not in cl.controller.workers
    # goodput continues after the failure window
    late = [r for r in cl.controller.completed
            if r.status == "ok" and r.arrival > 2.5]
    assert len(late) > 50


def test_elastic_add_worker_increases_capacity():
    # saturating load: one worker is the bottleneck, so elastic scale-out
    # must raise goodput
    models = {f"m{i}": table1_modeldef(f"m{i}") for i in range(8)}

    def run(two_workers: bool):
        cl = build_cluster(models, n_workers=1,
                           scheduler=ClockworkScheduler())
        clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.030,
                                    concurrency=16) for mid in models]
        cl.attach_clients(clients)
        if two_workers:
            def add():
                from repro.core.worker import SimBackend, Worker
                w = Worker("w_new", cl.loop, SimBackend(noise=0.0),
                           models, n_gpus=1)
                cl.workers.append(w)
                cl.controller.add_worker(w)
            cl.loop.schedule(0.5, add)
        s = cl.run(3.0)
        return s["goodput"]

    assert run(True) > run(False) * 1.3
