"""Workload generator coverage: MAF-like trace synthesizer shapes and
open/closed-loop client determinism under seeded RNG."""
import math

from repro.core.clock import EventLoop, VirtualClock
from repro.serving.workload import (ClosedLoopClient, OpenLoopClient,
                                    VariableRateClient, maf_like_rates)


def _loop():
    return EventLoop(VirtualClock())


# ------------------------------------------------------------ MAF-like trace

def test_maf_like_rates_covers_all_models_and_stays_nonnegative():
    n = 200
    fns = maf_like_rates(n, total_rate=1000.0, duration=120.0, seed=3)
    assert set(fns) == {f"m{i}" for i in range(n)}
    grid = [i * 7.3 for i in range(40)]
    for fn in fns.values():
        assert all(fn(t) >= 0.0 for t in grid)
        assert all(math.isfinite(fn(t)) for t in grid)


def test_maf_like_rates_shape_mix():
    """The synthesizer promises a mix of sustained / bursty / periodic /
    cold shapes: with enough models every category must appear —
    time-varying models (bursty/periodic spikes) and flat ones
    (sustained 3x boost vs cold 0.2x idle)."""
    n = 300
    fns = maf_like_rates(n, total_rate=1000.0, duration=120.0, seed=0)
    grid = [i * 1.7 for i in range(120)]
    varying = flat_hot = flat_cold = 0
    # base zipf weights, reconstructed to classify the flat shapes
    weights = [1.0 / (i + 1) ** 1.1 for i in range(n)]
    wsum = sum(weights)
    for i in range(n):
        fn = fns[f"m{i}"]
        vals = [fn(t) for t in grid]
        base = 1000.0 * weights[i] / wsum
        if max(vals) > min(vals) * 1.5 + 1e-12:
            varying += 1
        elif vals[0] >= base * 2.9:
            flat_hot += 1
        elif vals[0] <= base * 0.21:
            flat_cold += 1
    assert varying > 0.25 * n          # ~50% bursty+periodic by design
    assert flat_hot > 0.02 * n         # ~10% sustained
    assert flat_cold > 0.15 * n        # ~40% cold
    # spikes really spike: some model exceeds 5x its floor
    assert any(max(fn(t) for t in grid) >
               5.0 * min(fn(t) for t in grid) + 1e-12
               for fn in fns.values())


def test_maf_like_rates_deterministic_under_seed():
    a = maf_like_rates(50, total_rate=300.0, duration=60.0, seed=11)
    b = maf_like_rates(50, total_rate=300.0, duration=60.0, seed=11)
    c = maf_like_rates(50, total_rate=300.0, duration=60.0, seed=12)
    grid = [i * 0.9 for i in range(50)]
    assert all(a[m](t) == b[m](t) for m in a for t in grid)
    assert any(a[m](t) != c[m](t) for m in a for t in grid)


# ------------------------------------------------------- open-loop clients

def _collect_arrivals(make_client, t_end=10.0):
    loop = _loop()
    arrivals = []
    make_client(loop, lambda req: arrivals.append((req.model_id,
                                                   req.arrival)))
    loop.run_until(t_end)
    return arrivals


def test_open_loop_poisson_deterministic_and_bounded_by_stop():
    def mk(seed):
        return lambda loop, submit: OpenLoopClient(
            loop, submit, "m0", 0.1, rate=200.0, stop=5.0, seed=seed)

    a = _collect_arrivals(mk(7))
    b = _collect_arrivals(mk(7))
    c = _collect_arrivals(mk(8))
    assert a == b                       # bit-identical under equal seed
    assert a != c
    assert a, "no arrivals generated"
    assert all(t < 5.0 for _, t in a)
    # Poisson sanity: ~rate*stop arrivals, loose 4-sigma band
    assert abs(len(a) - 1000) < 4 * 1000 ** 0.5 + 50


def test_open_loop_zero_rate_sends_nothing():
    a = _collect_arrivals(lambda loop, submit: OpenLoopClient(
        loop, submit, "m0", 0.1, rate=0.0, stop=5.0, seed=1))
    assert a == []


def test_variable_rate_client_deterministic_and_thinned():
    def fn(t):
        return 50.0 if t < 2.0 else 5.0

    def mk(seed):
        return lambda loop, submit: VariableRateClient(
            loop, submit, "m0", 0.1, fn, stop=4.0, seed=seed,
            max_rate=100.0)

    a = _collect_arrivals(mk(3))
    b = _collect_arrivals(mk(3))
    assert a == b and a
    assert all(t < 4.0 for _, t in a)
    early = sum(1 for _, t in a if t < 2.0)
    late = len(a) - early
    # thinning must track the rate function: ~100 early vs ~10 late
    assert early > 3 * max(late, 1)


# ------------------------------------------------------ closed-loop client

def test_closed_loop_keeps_concurrency_outstanding():
    loop = _loop()
    inflight = []

    def submit(req):
        inflight.append(req)

    c = ClosedLoopClient(loop, submit, "m0", 0.1, concurrency=3)
    loop.run_until(0.0)
    assert len(inflight) == 3           # initial burst
    # responding to one triggers exactly one replacement
    done = inflight.pop(0)
    done.status = "ok"
    c.on_response(done)
    loop.run_until(0.001)
    assert len(inflight) == 3
    assert c.sent == 4
    # responses for other models are ignored
    class Other:
        model_id = "other"
    c.on_response(Other())
    loop.run_until(0.002)
    assert c.sent == 4


def test_closed_loop_respects_stop():
    loop = _loop()
    sent = []
    c = ClosedLoopClient(loop, sent.append, "m0", 0.1, concurrency=2,
                         stop=1.0)
    loop.run_until(0.0)
    assert len(sent) == 2
    loop.clock.advance_to(2.0)
    for r in list(sent):
        r.status = "ok"
        c.on_response(r)
    loop.run_until(3.0)
    assert len(sent) == 2               # nothing sent past stop
