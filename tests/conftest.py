# NOTE: no XLA_FLAGS here on purpose — tests run on the real single CPU
# device; only launch/dryrun.py forces 512 host devices (in its own process).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
