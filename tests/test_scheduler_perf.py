"""PR 2 regression suite: the incremental scheduler must make *identical*
decisions to the frozen pre-optimization implementation, and its tick must
stay cheap at paper-scale model counts.

Covers:
  * decision equivalence on seeded workloads (goodput/timeout/reject/action
    counts equal between `ClockworkScheduler` and the frozen
    `ReferenceClockworkScheduler`),
  * `_drop_hopeless` single-pass semantics (hopeless prefix, mid-queue
    hopeless entries, silently-dead requests),
  * the `_demands` O(1)-per-model fix,
  * per-model estimate memoization (profiler not re-queried per candidate),
  * a 2,000-model tick wall-clock smoke bound,
  * control-plane telemetry gauges (tick latency + event-loop throughput),
  * the controller residency index staying consistent with the mirrors.
"""
import time

import pytest

from repro.core.actions import Request
from repro.core.scheduler import TICK_LATENCY_GAUGE, ClockworkScheduler
from repro.core.scheduler_reference import ReferenceClockworkScheduler
from repro.serving.simulator import (PAPER_TABLE1, build_cluster,
                                     table1_modeldef)
from repro.serving.workload import ClosedLoopClient, OpenLoopClient

FAMILIES = list(PAPER_TABLE1)


def _models(n):
    return {f"m{i}": table1_modeldef(f"m{i}",
                                     family=FAMILIES[i % len(FAMILIES)])
            for i in range(n)}


# ------------------------------------------------------ decision equivalence

WORKLOADS = [
    # (n_models, seed, slo_s, kind) — closed-loop burst, open-loop spread,
    # and open-loop under memory pressure (LOAD/UNLOAD churn)
    (6, 1, 0.025, "closed"),
    (20, 2, 0.100, "open"),
    (12, 3, 0.050, "pressure"),
]


def _run_workload(sched_cls, workload):
    n, seed, slo, kind = workload
    models = _models(n)
    kw = dict(device_memory=2e9) if kind == "pressure" else {}
    cl = build_cluster(models, scheduler=sched_cls(), seed=seed, **kw)
    clients = []
    for i, mid in enumerate(models):
        if kind in ("open", "pressure"):
            clients.append(OpenLoopClient(cl.loop, cl.submit, mid, slo,
                                          rate=30.0, stop=1.5, seed=seed + i))
        else:
            clients.append(ClosedLoopClient(cl.loop, cl.submit, mid, slo,
                                            concurrency=4))
    cl.attach_clients(clients)
    s = cl.run(1.5)
    # full per-action trace (absolute ids excluded — the global id counters
    # keep running across runs): if any decision differed, batch sizes,
    # placements, timings, or the RNG draw sequence would diverge
    trace = [(r.action_type.value, r.model_id, r.worker_id, r.gpu_id,
              r.batch_size, r.status.value, r.t_start, r.t_end, r.duration,
              len(r.request_ids))
             for r in cl.controller.results_log]
    return {k: s[k] for k in ("goodput", "timeout", "rejected",
                              "actions", "total")}, trace


@pytest.mark.parametrize("workload", WORKLOADS,
                         ids=["closed", "open", "pressure"])
def test_decision_equivalence_seeded(workload):
    """Optimized and reference schedulers must make identical decisions —
    the full action/result sequence (types, models, placements, batch
    sizes, exact start/end times), not merely similar goodput."""
    opt, opt_trace = _run_workload(ClockworkScheduler, workload)
    ref, ref_trace = _run_workload(ReferenceClockworkScheduler, workload)
    assert opt == ref
    assert opt_trace == ref_trace
    assert opt["total"] > 0  # the workload actually exercised the system


def test_decision_equivalence_under_worker_failure():
    """Equivalence must survive the failure/requeue path too."""
    def run(sched_cls):
        models = _models(4)
        cl = build_cluster(models, n_workers=2, scheduler=sched_cls(),
                           preload=["m0", "m1", "m2", "m3"])
        clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.100,
                                    concurrency=6) for mid in models]
        cl.attach_clients(clients)
        cl.controller.start_heartbeats()
        cl.loop.schedule(0.8, cl.workers[0].fail)
        s = cl.run(2.0)
        return {k: s[k] for k in ("goodput", "timeout", "rejected",
                                  "actions", "dead_workers")}

    opt = run(ClockworkScheduler)
    ref = run(ReferenceClockworkScheduler)
    assert opt == ref
    assert opt["dead_workers"] == 1


# ----------------------------------------------------------- _drop_hopeless

def _scheduler_with_queue(sched_cls, reqs, est=0.003):
    cl = build_cluster({"m": table1_modeldef("m")}, scheduler=sched_cls())
    sched = cl.controller.scheduler
    cl.controller.profiler.seed("INFER", "m", 1, est)
    for r in reqs:
        cl.controller.requests[r.id] = r
        sched.on_request(r)
    return cl, sched


@pytest.mark.parametrize("sched_cls",
                         [ClockworkScheduler, ReferenceClockworkScheduler],
                         ids=["optimized", "reference"])
def test_drop_hopeless_rejects_exactly_the_hopeless_requests(sched_cls):
    # est=3ms, now=10ms: hopeless iff deadline < 13ms
    reqs = [
        Request(model_id="m", arrival=0.000, slo=0.001),   # dl 1ms  hopeless
        Request(model_id="m", arrival=0.000, slo=0.012),   # dl 12ms hopeless
        Request(model_id="m", arrival=0.000, slo=0.100),   # dl 100ms ok
        Request(model_id="m", arrival=0.000, slo=0.0125),  # dl 12.5ms
                                                           # hopeless mid-q
        Request(model_id="m", arrival=0.000, slo=0.200),   # dl 200ms ok
    ]
    cl, sched = _scheduler_with_queue(sched_cls, reqs)
    sched._drop_hopeless(0.010)
    q = sched.queues["m"]
    assert [r.slo for r in q] == [0.100, 0.200]      # survivors, in order
    assert cl.controller.stats["rejected"] == 3
    assert all(r.status == "rejected" for r in reqs if r.slo < 0.013)


@pytest.mark.parametrize("sched_cls",
                         [ClockworkScheduler, ReferenceClockworkScheduler],
                         ids=["optimized", "reference"])
def test_drop_hopeless_removes_dead_requests_without_rejecting(sched_cls):
    alive = Request(model_id="m", arrival=0.0, slo=0.500)
    dead = Request(model_id="m", arrival=0.0, slo=0.500)
    cl, sched = _scheduler_with_queue(sched_cls, [dead, alive])
    dead.status = "ok"   # completed while queued (failure/requeue race)
    if isinstance(sched, ClockworkScheduler):
        sched._scan_force.add("m")   # the on_result hint that triggers this
    sched._drop_hopeless(0.010)
    assert list(sched.queues["m"]) == [alive]
    assert cl.controller.stats["rejected"] == 0


@pytest.mark.parametrize("sched_cls",
                         [ClockworkScheduler, ReferenceClockworkScheduler],
                         ids=["optimized", "reference"])
def test_infinite_slo_requests_tick_without_error(sched_cls):
    """Best-effort (slo=inf) requests must not break the tick — regression
    for the min-deadline bound only being set for finite deadlines."""
    reqs = [Request(model_id="m", arrival=0.0, slo=float("inf")),
            Request(model_id="m", arrival=0.0, slo=0.100)]
    cl, sched = _scheduler_with_queue(sched_cls, reqs)
    sched.tick()                       # must not raise
    sched._drop_hopeless(0.010)
    assert len(sched.queues["m"]) == 2     # neither is hopeless
    assert cl.controller.stats["rejected"] == 0


def test_drop_hopeless_safe_against_synchronous_resubmit():
    """A client that submits synchronously from on_response must not jump
    the queue or poison the min-deadline bound."""
    hopeless = Request(model_id="m", arrival=0.0, slo=0.001)
    ok1 = Request(model_id="m", arrival=0.0, slo=0.100)
    ok2 = Request(model_id="m", arrival=0.0, slo=0.200)
    cl, sched = _scheduler_with_queue(ClockworkScheduler,
                                      [hopeless, ok1, ok2])
    resubmitted = Request(model_id="m", arrival=0.0105, slo=0.0125)

    def sync_resubmit(req):
        if req is hopeless:
            cl.controller.requests[resubmitted.id] = resubmitted
            sched.on_request(resubmitted)

    cl.controller.on_response = sync_resubmit
    sched._drop_hopeless(0.010)
    q = list(sched.queues["m"])
    # FIFO kept: survivors first, the mid-scan arrival at the tail
    assert q == [ok1, ok2, resubmitted]
    # the bound is the exact queue minimum — covering the new
    # (earliest-deadline) arrival and not degraded by pre-scan staleness —
    # so the next pass rejects it once it turns hopeless
    assert sched._qmin["m"] == resubmitted.deadline
    sched._drop_hopeless(0.021)        # 0.023 - 0.003 < 0.021 -> hopeless
    assert resubmitted.status == "rejected"
    assert list(sched.queues["m"]) == [ok1, ok2]


def test_drop_hopeless_single_pass_handles_long_queue_quickly():
    """The reference restarts its scan per deletion (O(n^2)); the rewrite
    must stay linear: dropping a 5,000-deep all-hopeless queue is instant."""
    reqs = [Request(model_id="m", arrival=0.0, slo=0.001)
            for _ in range(5000)]
    cl, sched = _scheduler_with_queue(ClockworkScheduler, reqs)
    t0 = time.perf_counter()
    sched._drop_hopeless(1.0)
    elapsed = time.perf_counter() - t0
    assert not sched.queues["m"]
    assert cl.controller.stats["rejected"] == 5000
    assert elapsed < 0.5    # generous; the O(n^2) version takes far longer


# ----------------------------------------------------------------- _demands

def test_demands_is_estimate_times_queue_depth():
    reqs = [Request(model_id="m", arrival=0.0, slo=10.0) for _ in range(7)]
    cl, sched = _scheduler_with_queue(ClockworkScheduler, reqs, est=0.004)
    d = sched._demands()
    assert d == {"m": pytest.approx(7 * 0.004)}
    # must match the reference's O(n) summation semantics
    cl2, ref = _scheduler_with_queue(ReferenceClockworkScheduler,
                                     [Request(model_id="m", arrival=0.0,
                                              slo=10.0) for _ in range(7)],
                                     est=0.004)
    assert ref._demands()["m"] == pytest.approx(d["m"])


# ------------------------------------------------------- estimate memoization

def test_estimates_memoized_until_profile_changes():
    reqs = [Request(model_id="m", arrival=0.0, slo=10.0) for _ in range(4)]
    cl, sched = _scheduler_with_queue(ClockworkScheduler, reqs)
    calls = {"n": 0}
    real = cl.controller.profiler.estimate

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    cl.controller.profiler.estimate = counting
    sched._est_mem.clear()
    for _ in range(50):
        sched._est_or_scale("m", 1)
        sched._est_or_scale("m", 4)
    assert calls["n"] == 2          # one profiler hit per (model, batch)

    # a result for the model invalidates its memo
    class R:
        model_id = "m"
        request_ids = ()
    sched.on_result(R())
    sched._est_or_scale("m", 1)
    assert calls["n"] == 3


# ------------------------------------------------------------ 2k-model tick

def test_two_thousand_model_tick_stays_fast():
    models = _models(2000)
    cl = build_cluster(models, scheduler=ClockworkScheduler(),
                       preload=[f"m{i}" for i in range(500)],
                       n_workers=2, gpus_per_worker=4)
    sched = cl.controller.scheduler
    for i in range(2000):
        sched.on_request(Request(model_id=f"m{i}", arrival=0.0, slo=0.100))
    t0 = time.perf_counter()
    ticks = 5
    for _ in range(ticks):
        sched.tick()
    mean = (time.perf_counter() - t0) / ticks
    # generous wall-clock bound: the pre-refactor scheduler takes far more
    assert mean < 0.25, f"mean 2000-model tick took {mean * 1e3:.1f}ms"


# ------------------------------------------------------------- telemetry

def test_tick_latency_and_event_loop_gauges_flow_into_reports():
    models = _models(4)
    cl = build_cluster(models, scheduler=ClockworkScheduler())
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.100,
                                concurrency=2) for mid in models]
    cl.attach_clients(clients)
    cl.run(0.5)
    rep = cl.telemetry_report()
    g = rep["gauges"][TICK_LATENCY_GAUGE]
    assert g["n"] > 0 and g["mean"] > 0 and g["p99"] >= g["p50"]
    assert rep["event_loop"]["events_total"] > 0
    assert rep["event_loop"]["events_per_wall_s"] > 0
    # raw samples are exported too
    samples = list(cl.recorder.iter_gauges(TICK_LATENCY_GAUGE))
    assert len(samples) == g["n"]
    assert all(s.value >= 0 for s in samples)


def test_gauges_survive_jsonl_export(tmp_path):
    models = _models(2)
    cl = build_cluster(models, scheduler=ClockworkScheduler())
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.100)
               for mid in models]
    cl.attach_clients(clients)
    cl.run(0.2)
    path = tmp_path / "telemetry.jsonl"
    n = cl.recorder.export_jsonl(str(path))
    lines = path.read_text().splitlines()
    assert len(lines) == n
    import json
    kinds = {json.loads(l)["kind"] for l in lines}
    assert "gauge" in kinds


# -------------------------------------------------------- residency index

def test_residency_index_matches_mirrors_after_churn():
    models = _models(12)
    cl = build_cluster(models, scheduler=ClockworkScheduler(),
                       device_memory=2e9, n_workers=2)
    clients = [OpenLoopClient(cl.loop, cl.submit, mid, 0.050, rate=30.0,
                              stop=1.0, seed=i)
               for i, mid in enumerate(models)]
    cl.attach_clients(clients)
    cl.run(1.0)
    c = cl.controller
    expect = {}
    for wid, m in c.workers.items():
        for gid in m.gpu_ids():
            for mid in m.gpus[gid].pagecache.resident:
                expect.setdefault(mid, set()).add((wid, gid))
    assert c._residency == expect
    for mid in expect:
        where = c.residency_where(mid)
        assert set(where) == expect[mid]
