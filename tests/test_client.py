"""Client-tier tests: RemoteClient decision-trace equivalence with
in-process clients, loadgen-workload determinism under seeded loopback,
client-side span stitching, and the hardened SUBMIT/RESPONSE path
(disconnect with requests in flight, malformed/mismatched frames,
unknown-model rejection)."""
import argparse
import math

import pytest

from repro.core.actions import Request
from repro.core.scheduler import ClockworkScheduler
from repro.runtime import protocol
from repro.runtime.harness import attach_remote_client
from repro.runtime.transport import LoopbackLink
from repro.serving.simulator import build_cluster, table1_modeldef
from repro.serving.workload import build_workload
from repro.telemetry.reports import client_breakdown


def _models(n):
    return {f"m{i}": table1_modeldef(f"m{i}") for i in range(n)}


WORKLOADS = ["open", "closed", "maf"]


def _run_seeded(kind, *, remote):
    """One seeded workload, driven either by in-process attach_clients or
    through a RemoteClient over zero-latency loopback."""
    models = _models(6)
    kw = dict(transport="loopback") if remote else {}
    cl = build_cluster(models, scheduler=ClockworkScheduler(), seed=4, **kw)
    rc = attach_remote_client(cl) if remote else None
    submit = rc.submit if remote else cl.submit
    gens = build_workload(cl.loop, submit, list(models), kind=kind,
                          rate=40.0, concurrency=4,
                          slo=0.030 if kind == "closed" else 0.100,
                          duration=1.2, seed=10)
    if remote:
        rc.attach(gens)
    else:
        cl.attach_clients(gens)
    cl.controller.start_heartbeats()
    s = cl.run(1.5)
    trace = [(r.action_type.value, r.model_id, r.worker_id, r.gpu_id,
              r.batch_size, r.status.value, r.t_start, r.t_end, r.duration,
              len(r.request_ids))
             for r in cl.controller.results_log]
    stats = {k: s[k] for k in ("goodput", "timeout", "rejected", "actions",
                               "total")}
    return stats, trace, rc


# ----------------------------------------------------- decision equivalence

@pytest.mark.parametrize("kind", WORKLOADS)
def test_remote_client_zero_latency_equals_in_process(kind):
    """Acceptance criterion: the same seeded workload driven through a
    RemoteClient over zero-latency loopback must produce the identical
    scheduler decision trace and goodput as in-process attach_clients —
    every SUBMIT/RESPONSE round-trips through the real wire codec, yet
    nothing about the decisions changes."""
    s_in, t_in, _ = _run_seeded(kind, remote=False)
    s_rc, t_rc, rc = _run_seeded(kind, remote=True)
    assert s_in == s_rc
    assert t_in == t_rc
    assert s_in["goodput"] > 0
    # client-observed counters agree with the controller's
    assert rc.summary()["goodput"] == s_in["goodput"]
    assert rc.in_flight == 0 and rc.lost == 0


def test_loadgen_workload_determinism_under_seeded_loopback():
    """The loadgen building blocks (build_workload + RemoteClient over a
    seeded lossy/jittery loopback) are bit-reproducible run to run."""
    def run():
        cl = build_cluster(_models(5), scheduler=ClockworkScheduler(),
                           seed=3, transport="loopback")
        rc = attach_remote_client(cl, latency=0.002, jitter=0.001,
                                  transport_seed=99)
        gens = build_workload(cl.loop, rc.submit, list(cl.models),
                              kind="maf", rate=30.0, slo=0.150,
                              duration=1.5, seed=21)
        rc.attach(gens)
        s = cl.run(2.0)
        return rc.summary(), tuple(rc.latencies), s["goodput"]

    a, b = run(), run()
    assert a == b
    assert a[0]["sent"] > 0 and a[0]["goodput"] > 0


# ------------------------------------------------------------ span stitching

def test_client_spans_stitch_remote_interval():
    cl = build_cluster(_models(2), scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0", "m1"])
    rc = attach_remote_client(cl)
    gens = build_workload(cl.loop, rc.submit, list(cl.models),
                          kind="open", rate=30.0, slo=0.100,
                          duration=1.0, seed=5)
    rc.attach(gens)
    cl.run(1.3)
    spans = list(rc.recorder.iter_spans())
    assert spans and all(s.status == "ok" for s in spans)
    for s in spans:
        assert not math.isnan(s.remote_arrival)
        assert not math.isnan(s.remote_completion)
        # zero-latency loopback: the only client-invisible time is the
        # worker's result-return delay
        assert s.net_overhead == pytest.approx(0.0005, abs=1e-6)
    rep = client_breakdown(spans)
    assert rep["client_total"]["count"] == len(spans)
    assert rep["net_overhead"]["median"] == pytest.approx(0.0005, abs=1e-6)
    assert rep["client_total"]["median"] > \
        rep["controller_total"]["median"]
    # spans survive a JSONL-style round-trip with the remote stamps
    d = spans[0].to_dict()
    s2 = type(spans[0]).from_dict(d)
    assert s2.remote_arrival == spans[0].remote_arrival
    assert s2.net_overhead == pytest.approx(spans[0].net_overhead)


# -------------------------------------------------- disconnect with in-flight

def test_client_disconnect_with_requests_in_flight_reclaims_state():
    """Regression for the client-channel lifecycle leak: a client that
    hangs up mid-request must disappear from the server's tracking, its
    _req_origin entries must be purged, and its completions dropped —
    not sent into a closed channel."""
    cl = build_cluster(_models(1), scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0"])
    server = cl.runtime.server
    rc = attach_remote_client(cl)
    responses = []
    rc._responders.append(responses.append)
    for _ in range(4):
        rc.submit(Request(model_id="m0", arrival=cl.loop.now(), slo=0.200))
    assert len(server.clients) == 1
    assert len(server._req_origin) == 4
    cl.loop.schedule(0.001, rc.close)      # hang up before any completion
    cl.run(1.0)
    # server state fully reclaimed
    assert not server.clients
    assert not server._req_origin
    # the requests were still served (the scheduler had committed)...
    assert cl.controller.stats["goodput"] == 4
    # ...but nothing was delivered to the departed client
    assert not responses
    assert rc.lost == 4 and rc.in_flight == 0
    # the loop stayed alive and the controller keeps serving others
    rc2 = attach_remote_client(cl, transport_seed=1234)
    rc2.submit(Request(model_id="m0", arrival=cl.loop.now(), slo=0.200))
    cl.run(cl.loop.now() + 1.0)
    assert rc2.summary()["goodput"] == 1
    assert not server._req_origin


# -------------------------------------------------------- malformed frames

def test_version_mismatch_first_frame_closes_channel_not_loop():
    cl = build_cluster(_models(1), scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0"])
    server = cl.runtime.server
    link = LoopbackLink(cl.loop)
    server.adopt(link.a)
    link.b.send({"v": 999, "kind": "hello", "worker_id": "evil",
                 "gpus": []})
    cl.run(0.1)
    assert link.closed                     # offender closed...
    assert server.bad_frames == 1
    assert "evil" not in cl.controller.workers
    # ...and the event loop survived: a well-behaved client still works
    rc = attach_remote_client(cl)
    rc.submit(Request(model_id="m0", arrival=cl.loop.now(), slo=0.200))
    cl.run(cl.loop.now() + 1.0)
    assert rc.summary()["goodput"] == 1


def test_malformed_client_frame_closes_and_purges():
    """A structurally bad frame mid-stream (missing keys) must close the
    client channel, purge its in-flight entries, and leave the loop
    alive."""
    cl = build_cluster(_models(1), scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0"])
    server = cl.runtime.server
    rc = attach_remote_client(cl)
    rc.submit(Request(model_id="m0", arrival=0.0, slo=0.200))
    rc.channel.send({"v": 1, "kind": "submit"})    # no "request" payload
    cl.run(1.0)
    assert server.bad_frames == 1
    assert rc.closed
    assert not server.clients and not server._req_origin
    # the controller itself is unharmed
    assert cl.controller.stats["goodput"] == 1     # first request served


def test_unknown_model_submit_rejected_without_entering_scheduler():
    cl = build_cluster(_models(1), scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0"])
    rc = attach_remote_client(cl)
    req = Request(model_id="no_such_model", arrival=0.0, slo=0.200)
    rc.submit(req)
    cl.run(0.5)
    assert rc.summary()["rejected"] == 1
    assert rc.in_flight == 0
    assert "no_such_model" not in cl.controller.scheduler.queues
    # a real request on the same channel still succeeds
    rc.submit(Request(model_id="m0", arrival=cl.loop.now(), slo=0.200))
    cl.run(cl.loop.now() + 1.0)
    assert rc.summary()["goodput"] == 1


# ------------------------------------------------------- malicious values

def test_malicious_field_values_close_channel_not_loop():
    """Type-level garbage (strings where arithmetic expects numbers,
    unhashable ids) must die at the frame boundary too."""
    cl = build_cluster(_models(1), scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0"])
    server = cl.runtime.server
    evil = [
        {"v": 1, "kind": "submit",
         "request": {"id": 1, "model_id": "m0", "arrival": "NOW",
                     "slo": []}},
        {"v": 1, "kind": "submit", "request": 42},
        {"v": 1, "kind": "hello", "worker_id": "wX",
         "gpus": [{"total_pages": "lots"}]},
    ]
    for msg in evil:
        link = LoopbackLink(cl.loop)
        server.adopt(link.a)
        link.b.send(msg)
        assert link.closed, msg
    assert server.bad_frames == len(evil)
    rc = attach_remote_client(cl)
    rc.submit(Request(model_id="m0", arrival=cl.loop.now(), slo=0.200))
    cl.run(cl.loop.now() + 1.0)
    assert rc.summary()["goodput"] == 1


# --------------------------------------------------------- loadgen process

def test_loadgen_child_cmd_is_flag_form_independent():
    """The parent rebuilds child commands from parsed args, so
    '--telemetry-jsonl=/x' and '--telemetry-jsonl /x' spellings behave
    identically; seeds spread and per-child streams get suffixes."""
    from repro.runtime import loadgen
    ns = argparse.Namespace(
        controller="h:1", workload="maf", n_models=2, rate=5.0,
        concurrency=4, slo=0.1, duration=1.0, drain=2.0,
        connect_timeout=10.0, seed=7, total_rate=40.0,
        telemetry_jsonl="/tmp/x.jsonl", rotate_bytes=None)
    cmd = loadgen._child_cmd(ns, 2)
    assert cmd[cmd.index("--seed") + 1] == "2007"
    assert cmd[cmd.index("--telemetry-jsonl") + 1] == "/tmp/x.jsonl.2"
    assert cmd[cmd.index("--total-rate") + 1] == "40.0"
    assert cmd[cmd.index("--processes") + 1] == "1"
    assert "--emit-latencies" in cmd


# ------------------------------------------------------- workload factory

def test_build_workload_rejects_unknown_kind():
    cl = build_cluster(_models(1), scheduler=ClockworkScheduler())
    with pytest.raises(ValueError, match="unknown workload kind"):
        build_workload(cl.loop, cl.submit, ["m0"], kind="bogus")


def test_build_workload_start_offset_shifts_generators():
    """A loadgen joins at loop.now() > 0: generators (including MAF rate
    functions) must be phase-shifted so the workload shape is the same
    regardless of join time."""
    def run(offset):
        cl = build_cluster(_models(3), scheduler=ClockworkScheduler(),
                           seed=2)
        if offset:
            cl.loop.run_until(offset)      # time passes before clients join
        gens = build_workload(cl.loop, cl.submit, list(cl.models),
                              kind="maf", rate=30.0, slo=0.150,
                              start=cl.loop.now(), duration=1.0, seed=7)
        cl.attach_clients(gens)
        cl.run(cl.loop.now() + 1.3)
        return sum(g.sent for g in gens)

    assert run(0.0) == run(5.0) > 0
