"""Training substrate: optimizers, microbatching equivalence, checkpoint
roundtrip/restart, data-pipeline determinism, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed.steps import make_train_step
from repro.models import params as pspec
from repro.models.registry import get_bundle
from repro.training.compression import (compress_with_error_feedback,
                                        dequantize_int8, quantize_int8)
from repro.training.optimizer import adafactor, adamw, clip_by_global_norm


def _setup(arch="qwen2-0.5b", B=4, S=32):
    cfg = get_smoke_config(arch)
    b = get_bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    src = SyntheticLM(cfg, ShapeSpec("t", "train", S, B), seed=0)
    batch = {k: jnp.asarray(v) for k, v in src.batch(0).items()}
    return cfg, b, params, batch


def test_loss_decreases_adamw():
    cfg, b, params, batch = _setup()
    opt = adamw(lr=3e-3)
    step = jax.jit(make_train_step(cfg, opt, chunk=16))
    opt_state = opt.init(params)
    losses = []
    for i in range(12):
        params, opt_state, m = step(params, opt_state, batch,
                                    jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_loss_decreases_adafactor():
    cfg, b, params, batch = _setup("gemma2-27b")
    opt = adafactor(lr=1e-2)
    step = jax.jit(make_train_step(cfg, opt, chunk=16))
    opt_state = opt.init(params)
    losses = []
    for i in range(12):
        params, opt_state, m = step(params, opt_state, batch,
                                    jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_microbatching_matches_full_batch_grads():
    cfg, b, params, batch = _setup(B=4)
    opt = adamw(lr=1e-3)
    s1 = jax.jit(make_train_step(cfg, opt, chunk=16, microbatches=1))
    s4 = jax.jit(make_train_step(cfg, opt, chunk=16, microbatches=4))
    p1, _, m1 = s1(params, opt.init(params), batch,
                   jnp.asarray(0, jnp.int32))
    p4, _, m4 = s4(params, opt.init(params), batch,
                   jnp.asarray(0, jnp.int32))
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    diffs = jax.tree.map(
        lambda a, c: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - c.astype(jnp.float32)))),
        p1, p4)
    assert max(jax.tree.leaves(diffs)) < 2e-2  # bf16 accumulation tolerance


def test_grad_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 100.0, "b": jnp.ones((3,)) * -100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(clipped))
    assert float(total) == pytest.approx(1.0, rel=1e-3)
    assert float(norm) == pytest.approx(100.0 * np.sqrt(7), rel=1e-4)


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    cfg, b, params, _ = _setup()
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 10, params)
    assert latest_step(d) == 10
    abs_p = pspec.abstract(b.spec())
    restored = restore_checkpoint(d, 10, abs_p)
    for a, c in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    # newer step wins; tmp dirs never count as checkpoints
    save_checkpoint(d, 20, params)
    assert latest_step(d) == 20
    assert not [f for f in os.listdir(d) if f.startswith(".tmp")]


def test_train_restart_resumes_identically(tmp_path):
    """Crash/restart: resuming from the checkpoint reproduces the exact
    same trajectory as the uninterrupted run (data pipeline resumability +
    checkpoint correctness together)."""
    from repro.launch.train import train
    d1 = str(tmp_path / "a")
    full = train("qwen2-0.5b", steps=8, batch=2, seq=32, smoke=True,
                 ckpt_dir=None)
    train("qwen2-0.5b", steps=4, batch=2, seq=32, smoke=True,
          ckpt_dir=d1, ckpt_every=4)
    resumed = train("qwen2-0.5b", steps=8, batch=2, seq=32, smoke=True,
                    ckpt_dir=d1, ckpt_every=100)
    np.testing.assert_allclose(resumed[-1], full[-1], rtol=1e-3, atol=1e-3)


def test_data_pipeline_deterministic_and_resumable():
    cfg = get_smoke_config("qwen2-0.5b")
    shape = ShapeSpec("t", "train", 16, 2)
    a = SyntheticLM(cfg, shape, seed=3)
    b = SyntheticLM(cfg, shape, seed=3)
    np.testing.assert_array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
    pf = Prefetcher(a, start_step=7)
    step, batch = next(pf)
    pf.close()
    assert step == 7
    np.testing.assert_array_equal(batch["tokens"], b.batch(7)["tokens"])
    # targets are the next-token shift of tokens
    t = a.batch(0)
    np.testing.assert_array_equal(t["tokens"][:, 1:], t["targets"][:, :-1])


@given(st.integers(1, 4), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_int8_quantization_bounded_error(ndim, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 40, ndim))
    x = jnp.asarray(rng.standard_normal(shape) * 10.0, jnp.float32)
    q, s, meta = quantize_int8(x)
    deq = dequantize_int8(q, s, meta)
    err = np.abs(np.asarray(deq - x))
    block_max = np.abs(np.asarray(x)).max()
    assert err.max() <= block_max / 127.0 + 1e-6


def test_error_feedback_converges_on_constant_gradient():
    g = {"w": jnp.full((300,), 0.01, jnp.float32)}
    acc = np.zeros(300)
    err = None
    for _ in range(50):
        deq, err = compress_with_error_feedback(g, err)
        acc += np.asarray(deq["w"])
    # with error feedback, long-run mean equals the true gradient
    np.testing.assert_allclose(acc / 50, 0.01, rtol=0.02)
