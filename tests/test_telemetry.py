"""Telemetry subsystem tests: ActionProfiler estimates, ProfileStore
round-trip, Recorder spans/records, the missed-result failure-detector fix,
and the e2e acceptance path (profiler CLI store -> serving run with zero
warmup re-measurements)."""
import json
import math

import pytest

from repro.core.actions import Action, ActionType, Request
from repro.core.clock import EventLoop, RealClock, VirtualClock
from repro.core.controller import Controller
from repro.core.predictor import ActionProfiler
from repro.core.scheduler import ClockworkScheduler
from repro.core.worker import ModelDef, SimBackend, Worker
from repro.serving.simulator import build_cluster, table1_modeldef
from repro.serving.workload import ClosedLoopClient
from repro.telemetry import (LatencyProfile, ProfileStore, Recorder,
                             latency_breakdown, prediction_error_report)


# ---------------------------------------------------------- ActionProfiler

def test_profiler_window_max_estimate():
    p = ActionProfiler(window=5)
    for d in (0.002, 0.003, 0.001):
        p.observe("INFER", "m", 1, d)
    assert p.estimate("INFER", "m", 1) == pytest.approx(0.003)
    # window slides: the old max falls out
    for d in (0.001,) * 5:
        p.observe("INFER", "m", 1, d)
    assert p.estimate("INFER", "m", 1) == pytest.approx(0.001)


def test_profiler_seed_fallback_until_first_observation():
    p = ActionProfiler()
    p.seed("INFER", "m", 1, 0.010)
    assert p.estimate("INFER", "m", 1) == pytest.approx(0.010)
    p.observe("INFER", "m", 1, 0.002)
    assert p.estimate("INFER", "m", 1) == pytest.approx(0.002)
    assert p.estimate("INFER", "m", 2) is None
    assert p.estimate_or("INFER", "m", 2, 0.007) == pytest.approx(0.007)


def test_profiler_over_under_error_accounting():
    p = ActionProfiler()
    p.seed("INFER", "m", 1, 0.010)
    p.observe("INFER", "m", 1, 0.004)   # pred 0.010 -> over by 0.006
    p.observe("INFER", "m", 1, 0.003)   # pred 0.004 -> over by 0.001
    p.observe("INFER", "m", 1, 0.009)   # pred 0.004 -> under by 0.005
    assert p.over_errors == pytest.approx([0.006, 0.001])
    assert p.under_errors == pytest.approx([0.005])


def test_profiler_history_snapshot():
    p = ActionProfiler(window=3)
    for d in (0.1, 0.2, 0.3, 0.4):
        p.observe("INFER", "m", 1, d)
    assert p.history() == {("INFER", "m", 1): [0.2, 0.3, 0.4]}


# ------------------------------------------------------------ ProfileStore

def test_profile_store_roundtrip_identical_estimates(tmp_path):
    src = ActionProfiler()
    for d in (0.002, 0.005, 0.003):
        src.observe("INFER", "m0", 1, d)
    for d in (0.011, 0.010):
        src.observe("LOAD", "m0", 1, d)
    store = ProfileStore()
    store.update_from_profiler(src)
    path = store.save(str(tmp_path / "profiles.json"))

    loaded = ProfileStore.load(path)
    dst = ActionProfiler()
    loaded.seed_profiler(dst)
    # seeded estimates equal the source's window-max estimates
    assert dst.estimate("INFER", "m0", 1) == \
        pytest.approx(src.estimate("INFER", "m0", 1))
    assert dst.estimate("LOAD", "m0", 1) == \
        pytest.approx(src.estimate("LOAD", "m0", 1))
    assert loaded.seed_dict() == store.seed_dict()


def test_profile_store_merge_and_version_check(tmp_path):
    store = ProfileStore()
    store.update("INFER", "m", 1, [0.002, 0.004])
    store.update("INFER", "m", 1, [0.003])
    p = store.get("INFER", "m", 1)
    assert p.count == 3
    assert p.max_s == pytest.approx(0.004)

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError, match="version"):
        ProfileStore.load(str(bad))


def test_latency_profile_from_durations():
    p = LatencyProfile.from_durations([0.001, 0.002, 0.003, 0.010])
    assert p.count == 4
    assert p.median_s == pytest.approx(0.002)
    assert p.max_s == pytest.approx(0.010)
    assert p.estimate == p.max_s


# ------------------------------------------------- Recorder (via simulator)

def _loaded_run(dur=2.0, **kw):
    models = {"m0": table1_modeldef("m0")}
    cl = build_cluster(models, scheduler=ClockworkScheduler(), **kw)
    client = ClosedLoopClient(cl.loop, cl.submit, "m0", 0.100, concurrency=4)
    cl.attach_clients([client])
    cl.run(dur)
    return cl


def test_recorder_spans_have_full_breakdown():
    cl = _loaded_run()
    spans = [s for s in cl.recorder.iter_spans() if s.status == "ok"]
    assert spans
    for s in spans:
        assert s.response >= s.dispatched >= s.queued >= s.arrival
        assert s.exec_end >= s.exec_start >= s.dispatched
        assert s.worker_id == "w0" and s.batch_size >= 1 and s.attempts >= 1
    # the first request of a cold model is attributed a LOAD phase
    assert any(s.cold_start and s.load_end >= s.load_start for s in spans)
    bd = latency_breakdown(cl.recorder.iter_spans())
    assert bd["total"]["count"] == len(spans)
    assert bd["exec"]["median"] > 0
    assert bd["statuses"].get("ok", 0) == len(spans)


def test_recorder_action_records_feed_prediction_error_report():
    cl = _loaded_run()
    recs = list(cl.recorder.iter_actions())
    assert recs
    succ = [a for a in recs if a.status == "SUCCESS" and
            a.predicted is not None]
    assert succ, "no predicted-vs-actual records"
    rep = prediction_error_report(recs)
    assert rep["over"]["n"] + rep["under"]["n"] == \
        len([a for a in succ if a.actual > 0])
    # paper Fig 9 scale: errors are micro-second scale under low noise
    assert rep["over"]["p99_us"] < 2000
    # worker-side stamps made it through
    assert all(a.t_start >= a.t_received >= 0 for a in succ)


def test_recorder_jsonl_export(tmp_path):
    cl = _loaded_run(dur=1.0)
    path = tmp_path / "telemetry.jsonl"
    n = cl.recorder.export_jsonl(str(path))
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == n > 0
    kinds = {l["kind"] for l in lines}
    assert kinds == {"span", "action", "gauge"}


def test_recorder_ring_buffer_bounds_memory():
    rec = Recorder(capacity=16)
    cl = _loaded_run(dur=1.0, recorder=rec)
    assert cl.recorder is rec
    assert len(rec.spans) <= 16 and len(rec.actions) <= 16
    assert rec.dropped_spans > 0 or rec.dropped_actions > 0


def test_simulator_runs_from_profile_store():
    # a store written by one run seeds the next cluster's profiler
    cl1 = _loaded_run()
    store = cl1.export_profile_store()
    assert len(store) > 0
    models = {"m0": table1_modeldef("m0")}
    cl2 = build_cluster(models, scheduler=ClockworkScheduler(),
                        profile_store=store)
    assert cl2.controller.profiler.estimate("INFER", "m0", 1) is not None
    client = ClosedLoopClient(cl2.loop, cl2.submit, "m0", 0.100,
                              concurrency=4)
    cl2.attach_clients([client])
    s = cl2.run(1.0)
    assert s["goodput"] > 0 and s["timeout"] == 0


# ------------------------------------------- missed-result failure detector

def _controller_with_worker(threshold=2):
    loop = EventLoop(VirtualClock())
    models = {"m": ModelDef("m", int(100e6), {("INFER", 1): 0.003})}
    w = Worker("w0", loop, SimBackend(noise=0.0), models, n_gpus=1)
    c = Controller(loop, models, ClockworkScheduler(),
                   missed_result_threshold=threshold)
    c.add_worker(w)
    w.pagecaches[0].alloc("m", 7)
    c.workers["w0"].gpus[0].pagecache.alloc("m", 7)
    return loop, w, c


def _infer_action(now):
    return Action(type=ActionType.INFER, model_id="m", worker_id="w0",
                  gpu_id=0, earliest=now, latest=now + 1.0,
                  expected_duration=0.003)


def test_single_missed_result_does_not_kill_worker():
    loop, w, c = _controller_with_worker(threshold=2)
    w.receive = lambda a: None          # swallow the action: no result
    c.send_action(_infer_action(loop.now()))
    loop.run_until(5.0)
    assert "w0" in c.workers            # survived one late result
    assert c.workers["w0"].missed_results == 1
    assert c.stats["dead_workers"] == 0


def test_missed_result_threshold_kills_worker():
    loop, w, c = _controller_with_worker(threshold=2)
    w.receive = lambda a: None
    c.send_action(_infer_action(loop.now()))
    c.send_action(_infer_action(loop.now()))
    loop.run_until(5.0)
    assert "w0" not in c.workers
    assert c.stats["dead_workers"] == 1


def test_successful_result_resets_missed_counter():
    loop, w, c = _controller_with_worker(threshold=2)
    w.receive = lambda a: None
    c.send_action(_infer_action(loop.now()))
    loop.run_until(5.0)
    assert c.workers["w0"].missed_results == 1
    del w.receive                       # restore the real method
    c.send_action(_infer_action(loop.now()))
    loop.run_until(10.0)
    assert "w0" in c.workers
    assert c.workers["w0"].missed_results == 0
    # a later lone miss still doesn't kill it: the counter restarted
    w.receive = lambda a: None
    c.send_action(_infer_action(loop.now()))
    loop.run_until(15.0)
    assert "w0" in c.workers


# --------------------------------------------- e2e: offline profile -> serve

def test_offline_profile_store_enables_zero_warmup_serving(tmp_path):
    """Acceptance: profiler CLI writes a store; a second serving run seeded
    from it performs zero warmup re-measurements and still serves."""
    from repro.serving.engine import (JaxBackend, make_resnet_model,
                                      seed_engines)
    from repro.telemetry import profiler as profcli

    mk = lambda: make_resnet_model("rt", scale=8, img=32, batches=(1,))
    store_path = str(tmp_path / "profiles.json")

    # --- run 1: offline profiling via the CLI plumbing
    store = profcli.build_store([("rt", mk)], reps=1)
    assert {k for k, _ in store.items()} == {("INFER", "rt", 1),
                                             ("LOAD", "rt", 1)}
    store.save(store_path)

    # --- run 2: fresh process state, seeded from the store
    store2 = ProfileStore.load(store_path)
    jm = mk()
    assert jm.warmup_count == 0
    profiles = seed_engines({"rt": jm}, store2)
    models = {"rt": jm.modeldef()}
    jm.compile()   # AOT compile (untimed) — distinct from re-measurement
    assert jm.warmup_count == 0, "modeldef() re-measured despite store"
    assert profiles[("INFER", "rt", 1)] == \
        pytest.approx(store2.get("INFER", "rt", 1).estimate)

    loop = EventLoop(RealClock())
    w = Worker("w0", loop, JaxBackend({"rt": jm}), models, n_gpus=1)
    c = Controller(loop, models, ClockworkScheduler(), action_delay=1e-4)
    c.add_worker(w, profiles)
    done = []
    c.on_response = done.append
    for _ in range(4):
        c.on_request(Request(model_id="rt", arrival=loop.now(), slo=10.0))
        loop.run_until(loop.now() + 0.05)
    loop.run_until(loop.now() + 3.0)
    ok = [r for r in done if r.status == "ok"]
    assert len(ok) >= 3, [r.status for r in done]
    assert jm.warmup_count == 0, "serving run re-measured the model"
    # live telemetry flowed: spans closed with exec stamps
    spans = [s for s in c.recorder.iter_spans() if s.status == "ok"]
    assert spans and all(not math.isnan(s.exec_end) for s in spans)


def test_update_store_never_recycles_seeded_estimates(tmp_path):
    """A store covering INFER but missing LOAD forces one load measurement;
    the INFER estimates it seeded must still not be folded back as if they
    were fresh samples."""
    from repro.serving.engine import make_resnet_model, seed_engines, \
        update_store

    mk = lambda: make_resnet_model("rt", scale=8, img=32, batches=(1,))
    store = ProfileStore()
    store.update("INFER", "rt", 1, [0.004])   # no ("LOAD", "rt", 1) entry

    jm = mk()
    seed_engines({"rt": jm}, store)
    assert jm.warmup_count > 0                # it had to measure LOAD
    fresh = jm.fresh_profiles()
    assert ("LOAD", "rt", 1) in fresh
    assert ("INFER", "rt", 1) not in fresh    # seeded, not measured

    before = store.get("INFER", "rt", 1)
    update_store({"rt": jm}, store)
    after = store.get("INFER", "rt", 1)
    assert after.count == before.count == 1   # no echo folded back
    assert store.get("LOAD", "rt", 1) is not None


def test_profiler_cli_main_writes_store(tmp_path):
    from repro.telemetry.profiler import main
    out = str(tmp_path / "cli_profiles.json")
    rc = main(["--quick", "--reps", "1", "--batches", "1", "--out", out])
    assert rc == 0
    store = ProfileStore.load(out)
    assert store.get("INFER", "resnet_tiny", 1) is not None
    assert store.get("LOAD", "resnet_tiny", 1) is not None


# ------------------------------------------------------ Recorder streaming

def _stream_some(rec, n):
    for i in range(n):
        rec.record_gauge("g", float(i), float(i) * 2.0)


def test_stream_to_writes_records_continuously(tmp_path):
    path = str(tmp_path / "stream.jsonl")
    rec = Recorder()
    rec.stream_to(path)
    req = Request(model_id="m", arrival=0.0, slo=0.1)
    rec.span_open(req, queued=0.001)
    req.status = "ok"
    rec.span_close(req, 0.02)
    _stream_some(rec, 3)
    rec.close_stream()
    lines = [json.loads(l) for l in open(path)]
    kinds = [l["kind"] for l in lines]
    assert kinds == ["span", "gauge", "gauge", "gauge"]
    assert rec.stream_lines == 4
    # the ring buffers are unaffected by streaming
    assert len(list(rec.iter_spans())) == 1


def test_stream_to_rotates_and_preserves_every_line(tmp_path):
    import os
    path = str(tmp_path / "rot.jsonl")
    rec = Recorder()
    rec.stream_to(path, rotate_bytes=2_000, rotate_keep=3)
    n = 500
    _stream_some(rec, n)
    rec.close_stream()
    assert rec.stream_rotations > 0
    files = sorted(p for p in os.listdir(tmp_path) if p.startswith("rot"))
    assert len(files) > 1                       # rotation happened
    assert len(files) <= 4                      # live + rotate_keep
    total = sum(1 for p in files
                for _ in open(os.path.join(tmp_path, p)))
    if len(files) < 4:
        assert total == n                       # nothing lost pre-evict
    # every surviving file holds valid JSONL gauge lines
    for p in files:
        for l in open(os.path.join(tmp_path, p)):
            assert json.loads(l)["kind"] == "gauge"
    # live file stays under the rotation bound (+ one record of slack)
    assert os.path.getsize(path) < 2_000 + 200


def test_stream_to_drops_oldest_beyond_keep(tmp_path):
    import os
    path = str(tmp_path / "keep.jsonl")
    rec = Recorder()
    rec.stream_to(path, rotate_bytes=500, rotate_keep=2)
    _stream_some(rec, 400)
    rec.close_stream()
    files = sorted(p for p in os.listdir(tmp_path) if p.startswith("keep"))
    assert set(files) <= {"keep.jsonl", "keep.jsonl.1", "keep.jsonl.2"}
    assert rec.stream_rotations > 2             # old generations evicted


def test_streamed_jsonl_reloads_into_typed_records(tmp_path):
    """load_jsonl is the offline-analysis inverse of stream_to: spans,
    actions, and gauges come back as typed records that feed the same
    report functions."""
    from repro.telemetry import load_jsonl
    path = str(tmp_path / "reload.jsonl")
    rec = Recorder()
    rec.stream_to(path)
    req = Request(model_id="m", arrival=0.5, slo=0.1)
    rec.span_open(req, queued=0.501)
    req.status = "ok"
    span = rec.span_close(req, 0.52)
    rec.record_gauge("g", 1.0, 2.5)
    rec.close_stream()
    got = load_jsonl(path)
    assert len(got["spans"]) == 1 and len(got["gauges"]) == 1
    s = got["spans"][0]
    assert s == span                      # NaN-free fields round-trip...
    assert math.isnan(s.dispatched)       # ...and null stamps back to NaN
    assert got["gauges"][0].value == 2.5
    # reloaded records feed the standard reports unchanged
    assert latency_breakdown(got["spans"])["statuses"] == {"ok": 1}
