"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + no NaNs (deliverable (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.configs.base import SHAPES, ShapeSpec, shape_applicable
from repro.configs.shapes import inputs_for
from repro.models.registry import get_bundle


def _real_batch(specs, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            out[k] = jnp.asarray(rng.integers(0, 64, v.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(v.shape) * 0.1, v.dtype)
    return out


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_forward(arch):
    cfg = get_smoke_config(arch)
    b = get_bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    shape = ShapeSpec("t", "train", 32, 2)
    batch = _real_batch(inputs_for(cfg, shape))
    logits = b.train_logits(params, batch, chunk=16)
    assert logits.shape[0] == 2
    assert logits.shape[-1] == cfg.vocab_padded
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_then_decode(arch):
    cfg = get_smoke_config(arch)
    b = get_bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    shape = ShapeSpec("p", "prefill", 32, 2)
    batch = _real_batch(inputs_for(cfg, shape))
    logits, cache = b.prefill(params, batch, chunk=16, cache_len=40)
    assert logits.shape[1] == 1 and not bool(jnp.isnan(logits).any())
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    start = batch["tokens"].shape[1]
    dlogits, cache2 = b.decode(params, cache, tok,
                               jnp.asarray(start, jnp.int32))
    assert dlogits.shape[1] == 1 and not bool(jnp.isnan(dlogits).any())
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if get_config(a).modality is None
                                  and not get_config(a).is_encdec])
def test_decode_matches_full_forward(arch):
    """Prefill(S) + decode(S) == train-mode forward over S+1 tokens."""
    from repro.models import lm
    cfg = get_smoke_config(arch)
    b = get_bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                       jnp.int32)
    full_logits, _ = lm.forward(params, cfg, mode="train", tokens=toks,
                                chunk=8)
    plogits, cache = lm.forward(params, cfg, mode="prefill",
                                tokens=toks[:, :S], chunk=8, cache_len=S + 8)
    dlogits, _ = lm.forward(params, cfg, mode="decode",
                            tokens=toks[:, S:S + 1], cache=cache,
                            cur_index=jnp.asarray(S, jnp.int32))
    V = cfg.vocab_size
    ref = np.asarray(full_logits[:, -1, :V], np.float32)
    got = np.asarray(dlogits[:, 0, :V], np.float32)
    pref = np.asarray(plogits[:, -1, :V], np.float32)
    fref = np.asarray(full_logits[:, S - 1, :V], np.float32)
    scale = max(np.abs(ref).max(), 1.0)
    assert np.abs(pref - fref).max() / max(np.abs(fref).max(), 1.0) < 1e-3
    assert np.abs(got - ref).max() / scale < 0.06  # bf16 accumulation noise


def test_shape_applicability_matrix():
    cells = [(a, s.name, shape_applicable(get_config(a), s))
             for a in ARCH_NAMES for s in SHAPES.values()]
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok in cells if not ok]
    # exactly the 7 pure-full-attention long_500k skips
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s in skipped)
