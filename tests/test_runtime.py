"""Distributed runtime tests: wire protocol round-trips, loopback
decision-trace equivalence with the in-process path, membership
(join/leave/failure), network-delay folding, and the daemon-shutdown
telemetry-flush regression."""
import math

import pytest

from repro.core.actions import Action, ActionType, Request, Result, \
    ResultStatus
from repro.core.scheduler import ClockworkScheduler
from repro.runtime import protocol
from repro.runtime.transport import LoopbackLink
from repro.runtime.worker import ClockSync
from repro.serving.simulator import build_cluster, table1_modeldef
from repro.serving.workload import ClosedLoopClient, OpenLoopClient


def _models(n):
    return {f"m{i}": table1_modeldef(f"m{i}") for i in range(n)}


# ----------------------------------------------------------------- protocol

def test_action_round_trip_is_exact():
    a = Action(type=ActionType.INFER, model_id="m0", worker_id="w0",
               gpu_id=1, earliest=1.23456789012345, latest=2.5,
               expected_duration=0.0031, batch_size=4,
               request_ids=(7, 8, 9))
    b = protocol.action_from_wire(protocol.action_to_wire(a))
    assert b == a                      # dataclass equality, floats exact


def test_result_round_trip_through_frames():
    r = Result(action_id=41, action_type=ActionType.LOAD, model_id="m1",
               worker_id="w2", gpu_id=0, status=ResultStatus.SUCCESS,
               t_start=0.125, t_end=0.25, duration=0.125, batch_size=1,
               request_ids=(), t_received=0.1)
    frames = list(protocol.iter_frames(
        protocol.encode_frame(protocol.result_msg(r))))
    assert len(frames) == 1
    assert protocol.result_from_wire(frames[0]["result"]) == r


def test_request_round_trip_preserves_infinite_slo():
    r = Request(model_id="m0", arrival=1.0, slo=float("inf"))
    d = list(protocol.iter_frames(
        protocol.encode_frame(protocol.submit_msg(r))))[0]
    r2 = protocol.request_from_wire(d["request"])
    assert r2.id == r.id and math.isinf(r2.slo)


def test_frame_decoder_handles_arbitrary_chunking():
    msgs = [protocol.ping(i, float(i)) for i in range(5)]
    blob = b"".join(protocol.encode_frame(m) for m in msgs)
    dec = protocol.FrameDecoder()
    out = []
    for i in range(0, len(blob), 3):   # 3-byte dribble
        out.extend(dec.feed(blob[i:i + 3]))
    assert [m["seq"] for m in out] == [0, 1, 2, 3, 4]


def test_version_mismatch_rejected():
    with pytest.raises(protocol.ProtocolError):
        protocol.check_version({"v": 999, "kind": "hello"})


def test_hello_profiles_round_trip():
    profiles = {("INFER", "m0", 1): 0.003, ("LOAD", "m0", 1): 0.009}
    msg = list(protocol.iter_frames(protocol.encode_frame(
        protocol.hello("w0", [{"total_pages": 10, "page_bytes": 1}],
                       profiles))))[0]
    assert protocol.profiles_from_hello(msg) == profiles


# --------------------------------------------------------------- clock sync

def test_clock_sync_identity_and_offset_recovery():
    s = ClockSync()
    assert s.to_local(5.0) == 5.0 and s.to_remote(5.0) == 5.0
    # remote clock = local + 100 (symmetric 10ms legs)
    s.observe(t0_local=1.0, t_remote=101.010, t1_local=1.020)
    assert s.offset == pytest.approx(100.0, abs=1e-9)
    # a higher-RTT sample must not displace the min-RTT estimate
    s.observe(t0_local=2.0, t_remote=102.5, t1_local=2.5)
    assert s.offset == pytest.approx(100.0, abs=1e-9)


# ------------------------------------------------- decision equivalence

EQ_WORKLOADS = ["closed", "open"]


def _run_seeded(kind, *, transport):
    models = _models(6)
    kw = dict(transport="loopback") if transport else {}
    cl = build_cluster(models, scheduler=ClockworkScheduler(), seed=4, **kw)
    clients = []
    for i, mid in enumerate(models):
        if kind == "open":
            clients.append(OpenLoopClient(cl.loop, cl.submit, mid, 0.100,
                                          rate=40.0, stop=1.2, seed=10 + i))
        else:
            clients.append(ClosedLoopClient(cl.loop, cl.submit, mid, 0.030,
                                            concurrency=4))
    cl.attach_clients(clients)
    cl.controller.start_heartbeats()
    s = cl.run(1.5)
    trace = [(r.action_type.value, r.model_id, r.worker_id, r.gpu_id,
              r.batch_size, r.status.value, r.t_start, r.t_end, r.duration,
              len(r.request_ids))
             for r in cl.controller.results_log]
    return {k: s[k] for k in ("goodput", "timeout", "rejected", "actions",
                              "total")}, trace


@pytest.mark.parametrize("kind", EQ_WORKLOADS)
def test_zero_latency_loopback_equals_in_process_decisions(kind):
    """Acceptance criterion: a seeded workload served through the
    zero-latency loopback transport must produce the *same scheduler
    decision trace* (full action/result sequence with exact timings) as
    the in-process path — every action and result round-trips through the
    real wire codec, yet nothing about the decisions changes."""
    s_in, t_in = _run_seeded(kind, transport=False)
    s_lb, t_lb = _run_seeded(kind, transport=True)
    assert s_in == s_lb
    assert t_in == t_lb
    assert s_in["total"] > 0 and s_in["goodput"] > 0


# ------------------------------------------------- latency / jitter / drop

def test_latency_folds_into_action_windows_and_slo_holds():
    models = _models(4)
    cl = build_cluster(models, scheduler=ClockworkScheduler(), seed=1,
                       transport="loopback", latency=0.002, jitter=0.001)
    assert all(m.net_delay == pytest.approx(0.0025)
               for m in cl.controller.workers.values())
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.060,
                                concurrency=4) for mid in models]
    cl.attach_clients(clients)
    s = cl.run(2.0)
    assert s["goodput"] > 0
    assert s["timeout"] == 0          # windows absorbed the network delay


def test_lossy_transport_is_deterministic_and_trips_failure_detection():
    def run():
        models = _models(4)
        cl = build_cluster(models, scheduler=ClockworkScheduler(), seed=1,
                           n_workers=2, transport="loopback", drop=0.2,
                           transport_seed=7)
        clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.060,
                                    concurrency=4) for mid in models]
        cl.attach_clients(clients)
        s = cl.run(2.0)
        return s, cl.runtime.dropped_frames

    s1, d1 = run()
    s2, d2 = run()
    assert (s1, d1) == (s2, d2)       # seeded loss is bit-reproducible
    assert d1 > 0
    # dropped results look like missed results -> workers declared dead
    assert s1["dead_workers"] > 0


def test_rtt_estimation_feeds_net_delay_over_loopback():
    models = _models(2)
    cl = build_cluster(models, scheduler=ClockworkScheduler(),
                       transport="loopback", latency=0.004,
                       fold_net_delay=False)
    cl.runtime.server.estimate_net_delay = True
    cl.controller.start_heartbeats()
    cl.run(5.0)
    m = next(iter(cl.controller.workers.values()))
    # PONG echoes the worker's reply turnaround (`hold`), so the estimate
    # is the pure one-way network delay — result_delay no longer inflates it
    assert m.net_delay == pytest.approx(0.004, rel=0.2)


def test_net_delay_estimate_excludes_worker_turnaround():
    """Regression for the net-delay overestimate: a worker that is *slow
    to answer* (large result_delay) must not look like a *distant* worker.
    The PONG's echoed hold duration is subtracted before the EWMA."""
    models = _models(2)
    cl = build_cluster(models, scheduler=ClockworkScheduler(),
                       transport="loopback", latency=0.004,
                       fold_net_delay=False)
    for w in cl.workers:
        w.result_delay = 0.080       # 20x the network leg
    cl.runtime.server.estimate_net_delay = True
    cl.controller.start_heartbeats()
    cl.run(5.0)
    m = next(iter(cl.controller.workers.values()))
    assert m.net_delay == pytest.approx(0.004, rel=0.2)
    assert m.net_delay < 0.010       # nowhere near latency + hold/2


# ------------------------------------------------------------- membership

def test_graceful_worker_leave_requeues_and_removes_mirror():
    models = _models(2)
    cl = build_cluster(models, n_workers=2, scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0", "m1"])
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.100,
                                concurrency=4) for mid in models]
    cl.attach_clients(clients)
    cl.loop.schedule(0.5, cl.runtime.hosts[0].shutdown)
    s = cl.run(2.0)
    assert "w0" not in cl.controller.workers
    assert "w1" in cl.controller.workers
    assert cl.controller.stats["dead_workers"] == 0   # graceful, not dead
    late_ok = [r for r in cl.controller.completed
               if r.status == "ok" and r.arrival > 1.0]
    assert late_ok                     # the survivor keeps serving


def test_connection_drop_marks_worker_failed():
    models = _models(1)
    cl = build_cluster(models, n_workers=2, scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0", "m0"])
    client = ClosedLoopClient(cl.loop, cl.submit, "m0", 0.100,
                              concurrency=8)
    cl.attach_clients([client])
    cl.loop.schedule(0.5, cl.runtime.links[0].close)   # yank the cable
    s = cl.run(2.0)
    assert cl.controller.stats["dead_workers"] == 1
    assert "w0" not in cl.controller.workers
    assert [r for r in cl.controller.completed
            if r.status == "ok" and r.arrival > 1.0]


def test_remote_request_client_submit_and_response():
    models = _models(1)
    cl = build_cluster(models, scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0"])
    link = LoopbackLink(cl.loop)
    cl.runtime.server.adopt(link.a)
    responses = []
    link.b.on_message = responses.append
    req = Request(model_id="m0", arrival=0.0, slo=0.200)
    link.b.send(protocol.submit_msg(req))
    cl.run(1.0)
    assert len(responses) == 1
    got = protocol.request_from_wire(responses[0]["request"])
    assert got.id == req.id and got.status == "ok"


def test_remote_clients_with_colliding_request_ids():
    """Request ids come from per-process counters, so two client
    processes WILL send the same id. The controller re-ids on admission
    and each RESPONSE echoes the client's own id — both clients must get
    exactly one response."""
    models = _models(1)
    cl = build_cluster(models, scheduler=ClockworkScheduler(),
                       transport="loopback", preload=["m0"])
    resp_a, resp_b = [], []
    links = []
    for sink in (resp_a, resp_b):
        link = LoopbackLink(cl.loop)
        cl.runtime.server.adopt(link.a)
        link.b.on_message = sink.append
        links.append(link)
    # one wire message, replayed verbatim from "two processes": same id
    msg = protocol.submit_msg(Request(model_id="m0", arrival=0.0,
                                      slo=0.200))
    wire_id = msg["request"]["id"]
    links[0].b.send(msg)
    links[1].b.send(msg)
    cl.run(1.0)
    assert len(resp_a) == 1 and len(resp_b) == 1
    for resp in (resp_a[0], resp_b[0]):
        got = protocol.request_from_wire(resp["request"])
        assert got.id == wire_id and got.status == "ok"
    assert cl.controller.stats["goodput"] == 2


# ------------------------------------------ shutdown telemetry flush (fix)

def test_daemon_shutdown_flushes_buffered_telemetry_spans():
    """Regression: short runs never fill the daemon's telemetry batch, so
    without the shutdown flush the controller would end the run with zero
    worker-side samples — and `telemetry_report` counts would diverge
    from a single-process run."""
    def workload(cl):
        # clients stop before the run ends: the post-shutdown drain must
        # not generate fresh (worker-less, hence rejected) requests
        clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.050,
                                    concurrency=4, stop=1.2)
                   for mid in cl.models]
        cl.attach_clients(clients)
        return cl.run(1.5)

    cl_in = build_cluster(_models(3), scheduler=ClockworkScheduler(),
                          seed=2)
    s_in = workload(cl_in)
    rep_in = cl_in.telemetry_report()

    cl_lb = build_cluster(_models(3), scheduler=ClockworkScheduler(),
                          seed=2, transport="loopback")
    s_lb = workload(cl_lb)
    # before shutdown: samples are buffered in the daemons, not delivered
    assert not [k for k in cl_lb.telemetry_report()["gauges"]
                if k.startswith("worker/")]
    for h in cl_lb.runtime.hosts:
        assert h.telemetry_flushes == 0 and h._pending
    cl_lb.shutdown()
    rep_lb = cl_lb.telemetry_report()
    # flushed worker gauges arrived
    assert [k for k in rep_lb["gauges"] if k.startswith("worker/")]
    # ...and the span/action populations match the single-process run
    assert s_in == s_lb
    assert rep_lb["breakdown"]["statuses"] == rep_in["breakdown"]["statuses"]
    assert rep_lb["breakdown"]["total"]["count"] == \
        rep_in["breakdown"]["total"]["count"]
    assert rep_lb["prediction_error"] == rep_in["prediction_error"]


def test_shutdown_flush_survives_transport_latency():
    """The final TELEMETRY frame is in flight when GOODBYE is sent; FIFO
    delivery + the drain in shutdown() must still land it."""
    cl = build_cluster(_models(2), scheduler=ClockworkScheduler(), seed=2,
                       transport="loopback", latency=0.003)
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.060,
                                concurrency=2) for mid in cl.models]
    cl.attach_clients(clients)
    cl.run(1.0)
    cl.shutdown()
    assert [k for k in cl.telemetry_report()["gauges"]
            if k.startswith("worker/")]
    for h in cl.runtime.hosts:
        assert h.closed and not h._pending


def test_controller_initiated_shutdown_flushes_over_latency():
    """Regression: on a controller-sent GOODBYE the daemon must not tear
    the channel down under its own in-flight flush — with loopback
    latency the final TELEMETRY/ACK frames are still scheduled when the
    daemon winds down."""
    cl = build_cluster(_models(2), scheduler=ClockworkScheduler(), seed=2,
                       transport="loopback", latency=0.003)
    clients = [ClosedLoopClient(cl.loop, cl.submit, mid, 0.060,
                                concurrency=2, stop=0.8)
               for mid in cl.models]
    cl.attach_clients(clients)
    cl.run(1.0)
    cl.runtime.server.shutdown()
    cl.loop.run_until(cl.loop.now() + 1.0)     # drain in-flight frames
    assert [k for k in cl.telemetry_report()["gauges"]
            if k.startswith("worker/")]
    for h in cl.runtime.hosts:
        assert h.closed and not h._pending
    assert cl.controller.stats["dead_workers"] == 0


# ------------------------------------------------------------ timer wheel

def test_missed_result_watch_uses_single_armed_sweep():
    """The detector must not schedule one loop event per action: with N
    outstanding watches the wheel keeps one armed sweep (plus at most one
    re-arm per fired sweep)."""
    models = _models(1)
    cl = build_cluster(models, scheduler=ClockworkScheduler(),
                       preload=["m0"])
    c = cl.controller
    heap_before = len(c.loop._heap)
    for i in range(500):
        c._watch_action_at(10.0 + i * 1e-6, 10_000_000 + i, "w0")
    # 500 watch entries, but only ONE new loop event (the armed sweep)
    assert len(c._watch_heap) >= 500
    assert len(c.loop._heap) == heap_before + 1
    cl.run(11.0)
    assert not c._watch_heap           # swept clean; nothing outstanding


def test_missed_results_still_kill_worker_via_wheel():
    models = _models(1)
    cl = build_cluster(models, n_workers=2, scheduler=ClockworkScheduler(),
                       preload=["m0", "m0"])
    client = ClosedLoopClient(cl.loop, cl.submit, "m0", 0.100,
                              concurrency=8)
    cl.attach_clients([client])
    # w0 silently dies: queued work never returns results
    cl.loop.schedule(0.5, cl.workers[0].fail)
    cl.run(3.0)
    assert cl.controller.stats["dead_workers"] == 1
    assert "w0" not in cl.controller.workers
