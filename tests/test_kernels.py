"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs ref.py oracles
(deliverable (c))."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.ssm import ssd_reference

RNG = np.random.default_rng(7)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


ATTN_CASES = [
    # B, Sq, Skv, H, K, D, causal, window, cap
    (2, 64, 64, 4, 2, 32, True, 0, 0.0),
    (1, 100, 100, 2, 2, 16, True, 24, 50.0),
    (2, 48, 48, 4, 1, 64, False, 0, 0.0),
    (1, 96, 96, 8, 8, 128, True, 0, 30.0),
    (1, 33, 33, 2, 1, 16, True, 7, 0.0),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_matches_oracle(case, dtype):
    B, Sq, Skv, H, K, D, causal, window, cap = case
    q, k, v = (_rand((B, Sq, H, D), dtype), _rand((B, Skv, K, D), dtype),
               _rand((B, Skv, K, D), dtype))
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              cap=cap, block_q=32, block_k=32)
    ke, ve = jnp.repeat(k, H // K, 2), jnp.repeat(v, H // K, 2)
    r = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D),
        ke.transpose(0, 2, 1, 3).reshape(B * H, Skv, D),
        ve.transpose(0, 2, 1, 3).reshape(B * H, Skv, D),
        causal=causal, window=window, cap=cap)
    r = r.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(r, np.float32),
                               rtol=tol, atol=tol)


DECODE_CASES = [
    # B, S, H, K, D, window, ring
    (2, 40, 4, 2, 32, 0, False),
    (1, 32, 2, 1, 16, 8, True),
    (2, 64, 8, 2, 64, 0, False),
    (1, 48, 4, 4, 128, 16, True),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DECODE_CASES)
def test_flash_decode_matches_oracle(case, dtype):
    B, S, H, K, D, window, ring = case
    q = _rand((B, 1, H, D), dtype)
    k, v = _rand((B, S, K, D), dtype), _rand((B, S, K, D), dtype)
    cur = 25
    if ring:
        j = jnp.arange(S)
        kpos = cur - jnp.mod(cur - j, S)
    else:
        kpos = jnp.arange(S)
    got = ops.flash_decode(q, k, v, kpos, cur, window=window, block_s=16)
    G = H // K
    qf = q.reshape(B, K, G, D).reshape(B * K, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    r = ref.flash_decode_ref(qf, kf, vf, kpos, cur, window=window
                             ).reshape(B, K * G, D)[:, None]
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


SSD_CASES = [
    (2, 64, 3, 16, 8, 16),
    (1, 50, 2, 8, 16, 16),   # ragged length -> padding path
    (1, 128, 4, 32, 16, 32),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_matches_oracle(case, dtype):
    B, L, H, P, N, chunk = case
    x = _rand((B, L, H, P), dtype) * 0.5
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (H,)), jnp.float32)
    bm = _rand((B, L, N), dtype) * 0.5
    cm = _rand((B, L, N), dtype) * 0.5
    y_ref, s_ref = ssd_reference(x, dt, a, bm, cm, chunk=chunk)
    y, s = ops.ssd(x, dt, a, bm, cm, chunk=chunk)
    tol = 3e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=tol, atol=tol)


def test_flash_xla_custom_vjp_grads():
    """The XLA flash path (dry-run fallback) has exact custom gradients."""
    import jax
    from repro.models.attention import chunked_attention, naive_attention
    q = _rand((2, 33, 2, 3, 16), jnp.float32)
    k = _rand((2, 33, 2, 16), jnp.float32)
    v = _rand((2, 33, 2, 16), jnp.float32)
    for causal, window, cap in [(True, 0, 0.0), (True, 7, 20.0),
                                (False, 0, 0.0)]:
        f_ref = lambda *a: (naive_attention(
            *a, causal=causal, window=window, cap=cap) ** 2).sum()
        f_got = lambda *a: (chunked_attention(
            *a, causal=causal, window=window, cap=cap, chunk=8) ** 2).sum()
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        gg = jax.grad(f_got, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gr, gg):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=3e-4, atol=3e-4)
