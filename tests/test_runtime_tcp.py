"""Multi-process TCP runtime smoke: controller + 2 worker daemons in
separate OS processes over localhost, serving a short open-loop workload
end to end with clean shutdown (the CI distributed smoke job runs the
same example)."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_tcp_demo_two_worker_daemons(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    jsonl = str(tmp_path / "workertel.jsonl")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples",
                                      "serve_distributed.py"),
         "--smoke", "--workers", "2", "--duration", "2.0",
         "--telemetry-jsonl", jsonl],
        env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "SMOKE OK" in proc.stdout
    # the printed summary is machine-readable: goodput > 0, never late
    payload = proc.stdout[proc.stdout.index("{"):
                          proc.stdout.rindex("}") + 1]
    out = json.loads(payload)
    assert out["goodput"] > 0
    assert out["timeout"] == 0
    assert out["worker_returncodes"] == [0, 0]
    assert out["dead_workers"] == 0
    # daemons streamed their local telemetry JSONL (Recorder.stream_to)
    for i in range(2):
        path = tmp_path / f"workertel.jsonl.w{i}"
        assert path.exists()
        lines = [json.loads(l) for l in path.read_text().splitlines()]
        assert lines and all(l["kind"] == "gauge" for l in lines)
