"""Multi-process TCP runtime smoke: controller + 2 worker daemons in
separate OS processes over localhost, serving a short open-loop workload
end to end with clean shutdown — and the full three-process topology
with the workload in its own loadgen process(es). (The CI distributed
smoke jobs run the same example.)"""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_tcp_demo_two_worker_daemons(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    jsonl = str(tmp_path / "workertel.jsonl")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples",
                                      "serve_distributed.py"),
         "--smoke", "--workers", "2", "--duration", "2.0",
         "--telemetry-jsonl", jsonl],
        env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "SMOKE OK" in proc.stdout
    # the printed summary is machine-readable: goodput > 0, never late
    payload = proc.stdout[proc.stdout.index("{"):
                          proc.stdout.rindex("}") + 1]
    out = json.loads(payload)
    assert out["goodput"] > 0
    assert out["timeout"] == 0
    assert out["worker_returncodes"] == [0, 0]
    assert out["dead_workers"] == 0
    # daemons streamed their local telemetry JSONL (Recorder.stream_to)
    for i in range(2):
        path = tmp_path / f"workertel.jsonl.w{i}"
        assert path.exists()
        lines = [json.loads(l) for l in path.read_text().splitlines()]
        assert lines and all(l["kind"] == "gauge" for l in lines)


def test_tcp_three_process_topology_with_loadgen():
    """Acceptance criterion: loadgen + controller + 2 worker daemons over
    localhost TCP — the workload lives in its own process(es) and the run
    reports nonzero *client-observed* goodput with p50/p99 latency."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples",
                                      "serve_distributed.py"),
         "--smoke", "--workers", "2", "--duration", "2.0",
         "--loadgen", "--loadgen-processes", "2"],
        env=env, capture_output=True, text=True, timeout=180)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "SMOKE OK" in proc.stdout
    out = json.loads(proc.stdout[proc.stdout.index("{"):
                                 proc.stdout.rindex("}") + 1])
    client = out["client"]
    assert client["returncode"] == 0
    assert client["goodput"] > 0
    assert client["goodput"] == out["goodput"]    # client view == server view
    assert client["timeout"] == 0 and client["lost"] == 0
    assert client["p50"] > 0 and client["p99"] >= client["p50"]
    # both child generators contributed and stitched net overhead
    assert len(client["children"]) == 2
    for ch in client["children"]:
        assert ch["sent"] > 0
        assert ch["report"]["net_overhead"]["median"] > 0
