"""Model bundle: one object per architecture exposing the three step
entrypoints (train logits / prefill / decode) plus spec & cache builders.
Family dispatch (dense / moe / ssm / hybrid / vlm / audio-encdec) happens
here; everything downstream (steps, dry-run, serving engine) is generic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm
from repro.models import params as pspec


class Bundle:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------- parameters
    def spec(self):
        if self.cfg.is_encdec:
            return encdec.encdec_spec(self.cfg)
        return lm.model_spec(self.cfg)

    def init(self, rng):
        return pspec.materialize(self.spec(), rng)

    def abstract_params(self):
        return pspec.abstract(self.spec())

    # ---------------- forward modes
    def train_logits(self, params, batch, chunk: int = 1024):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.train_logits(params, cfg, batch["frames"],
                                       batch["tokens"], chunk=chunk)
        if cfg.modality == "image_patches":
            logits, _ = lm.forward(params, cfg, mode="train",
                                   tokens=batch["tokens"],
                                   image_embeds=batch["image_embeds"],
                                   chunk=chunk)
            return logits[:, cfg.img_tokens:, :]
        logits, _ = lm.forward(params, cfg, mode="train",
                               tokens=batch["tokens"], chunk=chunk)
        return logits

    def prefill(self, params, batch, chunk: int = 1024, cache_len=None):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.prefill(params, cfg, batch["frames"],
                                  batch["tokens"], chunk=chunk,
                                  cache_len=cache_len)
        if cfg.modality == "image_patches":
            return lm.forward(params, cfg, mode="prefill",
                              tokens=batch["tokens"],
                              image_embeds=batch["image_embeds"],
                              chunk=chunk, cache_len=cache_len)
        return lm.forward(params, cfg, mode="prefill",
                          tokens=batch["tokens"], chunk=chunk,
                          cache_len=cache_len)

    def decode(self, params, cache, tokens, cur_index):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.decode(params, cfg, cache, tokens, cur_index)
        return lm.forward(params, cfg, mode="decode", tokens=tokens,
                          cache=cache, cur_index=cur_index)

    # ---------------- caches (decode state)
    def _dec_params_cfg(self):
        return self.cfg

    def init_cache(self, batch: int, max_len: int, cross_len: int = 0,
                   dtype=jnp.bfloat16):
        return lm.init_cache(self.cfg, batch, max_len, dtype, cross_len)

    def cache_abstract(self, batch: int, max_len: int, cross_len: int = 0,
                       dtype=jnp.bfloat16):
        return lm.cache_abstract(self.cfg, batch, max_len, dtype, cross_len)

    def cache_axes(self, cross_len: int = 0):
        return lm.cache_logical_axes(self.cfg, cross_len)


def get_bundle(cfg: ModelConfig) -> Bundle:
    return Bundle(cfg)
