"""Mamba2 (state-space duality) mixer block.

Implements the chunked SSD algorithm: intra-chunk attention-like einsums +
inter-chunk state passing via a short scan. Decode is an O(1) state update —
the property that makes SSMs the most Clockwork-friendly family (DECODE
latency independent of context length; see DESIGN.md §4).

The pure-jnp path here is also the oracle for the Pallas `ssd_scan` kernel
(`repro.kernels.ref` re-exports `ssd_reference`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamSpec


def mamba_spec(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    h = (d * s.expand) // s.head_dim        # number of SSD heads
    p, n, w = s.head_dim, s.d_state, s.conv_width
    return {
        "w_x": ParamSpec((d, h, p), ("d_model", "ssm_heads", "ssm_hd")),
        "w_z": ParamSpec((d, h, p), ("d_model", "ssm_heads", "ssm_hd")),
        "w_b": ParamSpec((d, n), ("d_model", "ssm_state")),
        "w_c": ParamSpec((d, n), ("d_model", "ssm_state")),
        "w_dt": ParamSpec((d, h), ("d_model", "ssm_heads")),
        "b_dt": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "a_log": ParamSpec((h,), ("ssm_heads",), init="ones",
                           dtype=jnp.float32),
        "d_skip": ParamSpec((h,), ("ssm_heads",), init="ones",
                            dtype=jnp.float32),
        "conv_x": ParamSpec((w, h, p), ("conv_w", "ssm_heads", "ssm_hd")),
        "conv_b": ParamSpec((w, n), ("conv_w", "ssm_state")),
        "conv_c": ParamSpec((w, n), ("conv_w", "ssm_state")),
        "norm": ParamSpec((h, p), ("ssm_heads", "ssm_hd"), init="zeros",
                          dtype=jnp.float32),
        "w_out": ParamSpec((h, p, d), ("ssm_heads", "ssm_hd", "d_model")),
    }


def ssm_heads(cfg: ModelConfig) -> int:
    return (cfg.d_model * cfg.ssm.expand) // cfg.ssm.head_dim


def causal_conv(x, kern):
    """Depthwise causal conv along axis 1. x (B,L,*C); kern (w,*C)."""
    w = kern.shape[0]
    pad = [(0, 0)] * x.ndim
    pad[1] = (w - 1, 0)
    xp = jnp.pad(x, pad)
    L = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(w):
        y = y + kern[i].astype(jnp.float32) * xp[:, i:i + L].astype(jnp.float32)
    return y.astype(x.dtype)


def conv_step(x_new, state, kern):
    """One-token conv. x_new (B,1,*C); state (B,w-1,*C)."""
    full = jnp.concatenate([state, x_new], axis=1)
    w = kern.shape[0]
    y = sum(kern[i].astype(jnp.float32) * full[:, i].astype(jnp.float32)
            for i in range(w))
    return y[:, None].astype(x_new.dtype), full[:, 1:]


def ssd_reference(x, dt, a, b, c, *, chunk: int, initial_state=None):
    """Chunked SSD. x (Bt,L,H,P); dt (Bt,L,H) f32; a (H,) f32 (negative);
    b, c (Bt,L,N). Returns (y (Bt,L,H,P), state (Bt,H,P,N) f32)."""
    Bt, L, H, Pd = x.shape
    N = b.shape[-1]
    Q = min(chunk, L)
    L0 = L
    if L % Q:        # pad tail: dt=0 => decay 1, zero input; state unaffected
        pad = Q - L % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        L += pad
    nc = L // Q

    xdt = (x.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    dA = dt * a                                      # (Bt,L,H) log-decay
    dA_c = dA.reshape(Bt, nc, Q, H)
    cum = jnp.cumsum(dA_c, axis=2)                   # (Bt,nc,Q,H)
    x_c = xdt.reshape(Bt, nc, Q, H, Pd)
    b_c = b.reshape(Bt, nc, Q, N)
    c_c = c.reshape(Bt, nc, Q, N)

    # intra-chunk
    scores = jnp.einsum("bcqn,bckn->bcqk", c_c, b_c).astype(jnp.float32)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (Bt,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    w_full = scores[..., None] * lmat                # (Bt,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp",
                         w_full.astype(x.dtype), x_c)

    # chunk summary states
    to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (Bt,nc,Q,H)
    s_chunk = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                         to_end, b_c.astype(jnp.float32),
                         x_c.astype(jnp.float32))    # (Bt,nc,H,P,N)

    # inter-chunk state recurrence
    t_total = jnp.exp(cum[:, :, -1, :])              # (Bt,nc,H)
    s0 = (jnp.zeros((Bt, H, Pd, N), jnp.float32)
          if initial_state is None else initial_state)

    def body(s_in, xs):
        t_c, s_c = xs
        s_out = s_in * t_c[:, :, None, None] + s_c
        return s_out, s_in

    s_last, s_ins = jax.lax.scan(
        body, s0, (t_total.swapaxes(0, 1), s_chunk.swapaxes(0, 1)))
    s_ins = s_ins.swapaxes(0, 1)                     # (Bt,nc,H,P,N) incoming

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         c_c.astype(jnp.float32), jnp.exp(cum), s_ins)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(Bt, L, H, Pd)
    return y[:, :L0].astype(x.dtype), s_last


def _branches(p, cfg: ModelConfig, x):
    """Project input to SSD operands (pre-conv)."""
    xh = jnp.einsum("bld,dhp->blhp", x, p["w_x"])
    z = jnp.einsum("bld,dhp->blhp", x, p["w_z"])
    b = jnp.einsum("bld,dn->bln", x, p["w_b"])
    c = jnp.einsum("bld,dn->bln", x, p["w_c"])
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x.astype(jnp.float32),
                   p["w_dt"].astype(jnp.float32)) + p["b_dt"].astype(jnp.float32))
    return xh, z, b, c, dt


def _finish(p, cfg: ModelConfig, y, z, xh):
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=(-2, -1), keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["norm"])
    g = constrain(g.astype(xh.dtype), "batch", "seq", "ssm_heads", "ssm_hd")
    return jnp.einsum("blhp,hpd->bld", g, p["w_out"])


def mamba_full(p, cfg: ModelConfig, x):
    """Train/prefill. x (B,L,d) -> (y, state-dict)."""
    s = cfg.ssm
    xh, z, b, c, dt = _branches(p, cfg, x)
    conv_x_state = xh[:, -(s.conv_width - 1):]       # pre-activation tails
    conv_b_state = b[:, -(s.conv_width - 1):]
    conv_c_state = c[:, -(s.conv_width - 1):]
    xh = jax.nn.silu(causal_conv(xh, p["conv_x"]).astype(jnp.float32)
                     ).astype(x.dtype)
    b = jax.nn.silu(causal_conv(b, p["conv_b"]).astype(jnp.float32)
                    ).astype(x.dtype)
    c = jax.nn.silu(causal_conv(c, p["conv_c"]).astype(jnp.float32)
                    ).astype(x.dtype)
    xh = constrain(xh, "batch", "seq", "ssm_heads", "ssm_hd")
    a = -jnp.exp(p["a_log"])
    y, s_last = ssd_reference(xh, dt, a, b, c, chunk=s.chunk)
    out = _finish(p, cfg, y.astype(jnp.float32), z, xh)
    state = {"ssm": s_last, "conv_x": conv_x_state,
             "conv_b": conv_b_state, "conv_c": conv_c_state}
    return constrain(out, "batch", "seq", "d_model"), state


def mamba_decode(p, cfg: ModelConfig, x, state):
    """One token. x (B,1,d). state from make_state/mamba_full."""
    xh, z, b, c, dt = _branches(p, cfg, x)
    xh, cx = conv_step(xh, state["conv_x"], p["conv_x"])
    b, cb = conv_step(b, state["conv_b"], p["conv_b"])
    c, cc = conv_step(c, state["conv_c"], p["conv_c"])
    xh = jax.nn.silu(xh.astype(jnp.float32)).astype(x.dtype)
    b = jax.nn.silu(b.astype(jnp.float32)).astype(x.dtype)
    c = jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
    a = -jnp.exp(p["a_log"])                          # (H,)
    dA = jnp.exp(dt[:, 0] * a)                        # (B,H)
    xdt = xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None]
    s_new = (state["ssm"] * dA[:, :, None, None]
             + jnp.einsum("bhp,bn->bhpn", xdt, b[:, 0].astype(jnp.float32)))
    s_new = constrain(s_new, "batch", "ssm_heads", "ssm_hd", "ssm_state")
    y = jnp.einsum("bhpn,bn->bhp", s_new, c[:, 0].astype(jnp.float32))
    out = _finish(p, cfg, y[:, None], z, xh)
    state = {"ssm": s_new, "conv_x": cx, "conv_b": cb, "conv_c": cc}
    return constrain(out, "batch", "seq", "d_model"), state


def mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    h = ssm_heads(cfg)
    w = s.conv_width - 1
    return {
        "ssm": jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
        "conv_x": jnp.zeros((batch, w, h, s.head_dim), dtype),
        "conv_b": jnp.zeros((batch, w, s.d_state), dtype),
        "conv_c": jnp.zeros((batch, w, s.d_state), dtype),
    }


def mamba_state_axes():
    return {
        "ssm": ("batch", "ssm_heads", "ssm_hd", "ssm_state"),
        "conv_x": ("batch", "conv_w", "ssm_heads", "ssm_hd"),
        "conv_b": ("batch", "conv_w", "ssm_state"),
        "conv_c": ("batch", "conv_w", "ssm_state"),
    }
