"""GQA attention: full/sliding-window, chunked (flash-style) prefill, cached
decode, cross-attention. Works under both TP modes (see distributed/sharding).

Memory note: prefill at 32k tokens cannot materialize (Sq, Skv) scores, so the
XLA path scans over KV chunks with an online softmax (the Pallas kernel in
``repro.kernels.flash_attention`` is the TPU production path; this module is
the semantically identical pure-XLA fallback the dry-run lowers).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, current_mesh_rules
from repro.models.flash_xla import flash_attention_xla
from repro.models.layers import rope, softcap
from repro.models.params import ParamSpec

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attn_spec(cfg: ModelConfig, cross: bool = False):
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "w_q": ParamSpec((d, h, hd), ("d_model_tp", "heads", "head_dim")),
        "w_k": ParamSpec((d, k, hd), ("d_model_tp", "kv_heads", "head_dim")),
        "w_v": ParamSpec((d, k, hd), ("d_model_tp", "kv_heads", "head_dim")),
        "w_o": ParamSpec((h, hd, d), ("heads_o", "head_dim", "d_model_out")),
    }
    if cfg.qkv_bias:
        s["b_q"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        s["b_k"] = ParamSpec((k, hd), ("kv_heads", "head_dim"), init="zeros")
        s["b_v"] = ParamSpec((k, hd), ("kv_heads", "head_dim"), init="zeros")
    return s


def _project_qkv(p, x, x_kv=None, positions=None, kv_positions=None,
                 theta: float = 10000.0, use_rope: bool = True):
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhx->bshx", x, p["w_q"])
    k = jnp.einsum("bsd,dkx->bskx", x_kv, p["w_k"])
    v = jnp.einsum("bsd,dkx->bskx", x_kv, p["w_v"])
    if "b_q" in p:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    if use_rope:
        q = rope(q, positions, theta)
        k = rope(k, kv_positions if kv_positions is not None else positions,
                 theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _should_expand_kv(cfg: ModelConfig) -> bool:
    """Expand KV to full heads when heads are mesh-sharded but KV heads are
    not shardable (heads mode with kv_heads not divisible)."""
    mesh, rules = current_mesh_rules()
    if rules is None:
        return False
    return rules.get("_mode") == "heads" and not rules.get("kv_heads")


def _context_segments() -> int:
    """Segment count for the combine-once context-parallel flash: the
    model-axis size when context mode shards the KV sequence."""
    mesh, rules = current_mesh_rules()
    if mesh is None or rules is None or rules.get("_mode") != "context":
        return 0
    return int(mesh.shape.get("model", 0))


def _mask(qpos, kpos, *, causal: bool, window: int):
    """Additive mask (B,1,1,Sq,Skv). qpos (B,Sq); kpos (Skv,)."""
    d = qpos[:, :, None] - kpos[None, None, :]        # (B,Sq,Skv)
    m = (kpos >= 0)[None, None, :] & jnp.ones_like(d, bool)
    if causal:
        m &= d >= 0
    if window:
        m &= d < window
    add = jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)
    return add[:, None, None]                          # (B,1,1,Sq,Skv)


def naive_attention(q, k, v, *, causal: bool, window: int, cap: float):
    """Reference attention materializing full scores (tests/small inputs).

    q (B,Sq,K,G,D); k,v (B,Skv,K,D)."""
    B, Sq, K, G, D = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", (q * D ** -0.5).astype(q.dtype), k
                   ).astype(jnp.float32)
    s = softcap(s, cap)
    qpos = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    s = s + _mask(qpos, jnp.arange(Skv), causal=causal, window=window)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)


def chunked_attention(q, k, v, *, causal: bool, window: int, cap: float,
                      chunk: int = 1024, kv_dim_is_heads: bool = False):
    """Memory-bounded attention: flash_xla custom-vjp path (segmented
    combine-once variant under context-parallel sharding)."""
    return flash_attention_xla(q, k, v, causal=causal, window=window,
                               cap=cap, chunk=chunk,
                               kv_dim_is_heads=kv_dim_is_heads,
                               segments=_context_segments())


def attend_full(p, cfg: ModelConfig, x, *, kind: str, positions,
                x_kv=None, kv_positions=None, cross: bool = False,
                causal: bool = True, chunk: int = 1024):
    """Training / prefill attention. Returns (y, (k, v)) — k/v post-RoPE,
    unexpanded, for cache construction."""
    q, k, v = _project_qkv(p, x, x_kv=x_kv, positions=positions,
                           kv_positions=kv_positions,
                           theta=cfg.rope_theta, use_rope=not cross)
    B, Sq, H, D = q.shape
    K = k.shape[2]
    is_causal = causal and not cross
    if _should_expand_kv(cfg):
        ke = jnp.repeat(k, H // K, axis=2)
        ve = jnp.repeat(v, H // K, axis=2)
        qg = q[:, :, :, None, :]               # (B,S,H,1,D)
        out = chunked_attention(
            qg, ke, ve,
            causal=is_causal, window=cfg.window if kind == "local" else 0,
            cap=cfg.attn_softcap, chunk=chunk, kv_dim_is_heads=True)
        y = out.reshape(B, Sq, H, D)
    else:
        qg = q.reshape(B, Sq, K, H // K, D)
        out = chunked_attention(
            qg, k, v,
            causal=is_causal, window=cfg.window if kind == "local" else 0,
            cap=cfg.attn_softcap, chunk=chunk)
        y = out.reshape(B, Sq, H, D)
    y = constrain(y, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshx,hxd->bsd", y, p["w_o"])
    return constrain(y, "batch", "seq", "d_model"), (k, v)


def make_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Abstract/zero cache for one attention layer."""
    W = cfg.window if (kind == "local" and cfg.sliding_kv and cfg.window) else 0
    S = min(max_len, W) if W else max_len
    shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_axes():
    return ("batch", "seq_kv", "kv_heads", "head_dim")


def prefill_into_cache(cfg: ModelConfig, kind: str, k, v, max_len: int):
    """Build a decode cache from prefill K/V (ring-packed for local layers)."""
    B, S, K, D = k.shape
    W = cfg.window if (kind == "local" and cfg.sliding_kv and cfg.window) else 0
    cap = min(max_len, W) if W else max_len
    if S == cap:
        return {"k": k, "v": v}
    if S > cap:                       # keep last `cap`, ring-packed
        shift = (S - cap) % cap
        kk = jnp.roll(k[:, S - cap:], shift, axis=1)
        vv = jnp.roll(v[:, S - cap:], shift, axis=1)
        return {"k": kk, "v": vv}
    pad = ((0, 0), (0, cap - S), (0, 0), (0, 0))
    return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}


def attend_decode(p, cfg: ModelConfig, x, cache, cur_index, *, kind: str,
                  cross: bool = False):
    """One-token decode. x (B,1,d). Returns (y, new_cache)."""
    B = x.shape[0]
    pos = jnp.full((B, 1), cur_index, jnp.int32)
    q = jnp.einsum("bsd,dhx->bshx", x, p["w_q"])
    if "b_q" in p:
        q = q + p["b_q"]
    if not cross:
        q = rope(q, pos, cfg.rope_theta)
    H, D = q.shape[2], q.shape[3]
    K = cfg.n_kv_heads

    k_all, v_all = cache["k"], cache["v"]
    S = k_all.shape[1]
    W = cfg.window if (kind == "local" and cfg.sliding_kv and cfg.window) else 0

    if cross:
        new_cache = cache
        kpos = jnp.arange(S)
        window = 0
        causal = False
    else:
        k_new = jnp.einsum("bsd,dkx->bskx", x, p["w_k"])
        v_new = jnp.einsum("bsd,dkx->bskx", x, p["w_v"])
        if "b_k" in p:
            k_new, v_new = k_new + p["b_k"], v_new + p["b_v"]
        k_new = rope(k_new, pos, cfg.rope_theta).astype(k_all.dtype)
        v_new = v_new.astype(v_all.dtype)
        slot = jnp.mod(cur_index, S) if (W and S == W) else cur_index
        k_all = jax.lax.dynamic_update_slice(k_all, k_new, (0, slot, 0, 0))
        v_all = jax.lax.dynamic_update_slice(v_all, v_new, (0, slot, 0, 0))
        k_all = constrain(k_all, *cache_axes())
        v_all = constrain(v_all, *cache_axes())
        new_cache = {"k": k_all, "v": v_all}
        if W and S == W:              # ring buffer: absolute pos per slot
            j = jnp.arange(S)
            kpos = cur_index - jnp.mod(cur_index - j, S)
        else:
            kpos = jnp.arange(S)
        window = W
        causal = True

    qg = (q * (D ** -0.5)).reshape(B, 1, K, H // K, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_all).astype(jnp.float32)
    s = softcap(s, cfg.attn_softcap)
    mask = kpos <= cur_index if causal else jnp.ones_like(kpos, bool)
    if window:
        mask &= kpos > cur_index - window
    if not cross:
        mask &= kpos >= 0
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    s = constrain(s, "batch", "kv_heads", "heads", "seq", "seq_kv")
    m = s.max(axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    w = e / e.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v_all.dtype), v_all)
    y = out.reshape(B, 1, H, D)
    y = jnp.einsum("bshx,hxd->bsd", y, p["w_o"])
    return constrain(y, "batch", "seq", "d_model"), new_cache
