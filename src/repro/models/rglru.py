"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Linear recurrence h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t) with
input-dependent gates. Train/prefill uses `lax.associative_scan` (the
TPU-native parallel-scan formulation); decode is an O(1) state update.

Simplification vs. the paper: the recurrence/input gates are per-channel
(diagonal) rather than block-diagonal per head — noted in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamSpec
from repro.models.ssm import causal_conv, conv_step


def rglru_spec(cfg: ModelConfig):
    d = cfg.d_model
    r = cfg.rglru.d_rnn or d
    w = cfg.rglru.conv_width
    return {
        "w_x": ParamSpec((d, r), ("d_model", "d_rnn")),
        "w_gate": ParamSpec((d, r), ("d_model", "d_rnn")),
        "conv_k": ParamSpec((w, r), ("conv_w", "d_rnn")),
        "conv_b": ParamSpec((r,), ("d_rnn",), init="zeros"),
        "lam": ParamSpec((r,), ("d_rnn",), init="ones", dtype=jnp.float32),
        "a_w": ParamSpec((r,), ("d_rnn",), init="ones", dtype=jnp.float32),
        "a_b": ParamSpec((r,), ("d_rnn",), init="zeros", dtype=jnp.float32),
        "i_w": ParamSpec((r,), ("d_rnn",), init="ones", dtype=jnp.float32),
        "i_b": ParamSpec((r,), ("d_rnn",), init="zeros", dtype=jnp.float32),
        "w_out": ParamSpec((r, d), ("d_rnn", "d_model")),
    }


def _gates(p, cfg: ModelConfig, xb32):
    r_gate = jax.nn.sigmoid(xb32 * p["a_w"] + p["a_b"])
    i_gate = jax.nn.sigmoid(xb32 * p["i_w"] + p["i_b"])
    log_a = -cfg.rglru.c * jax.nn.softplus(p["lam"]) * r_gate
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i_gate * xb32)
    return a, b


def rglru_full(p, cfg: ModelConfig, x):
    """x (B,L,d) -> (y, state)."""
    w = cfg.rglru.conv_width
    xb = jnp.einsum("bld,dr->blr", x, p["w_x"])
    conv_state = xb[:, -(w - 1):]
    xb = causal_conv(xb, p["conv_k"]) + p["conv_b"]
    xb = constrain(xb, "batch", "seq", "d_rnn")
    xb32 = xb.astype(jnp.float32)
    a, b = _gates(p, cfg, xb32)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = constrain(h, "batch", "seq", "d_rnn")
    gate = jax.nn.gelu(
        jnp.einsum("bld,dr->blr", x, p["w_gate"]).astype(jnp.float32),
        approximate=True)
    y = jnp.einsum("blr,rd->bld", (h * gate).astype(x.dtype), p["w_out"])
    state = {"h": h[:, -1], "conv": conv_state}
    return constrain(y, "batch", "seq", "d_model"), state


def rglru_decode(p, cfg: ModelConfig, x, state):
    """One token. x (B,1,d)."""
    xb = jnp.einsum("bld,dr->blr", x, p["w_x"])
    xb, conv_state = conv_step(xb, state["conv"], p["conv_k"])
    xb = xb + p["conv_b"]
    xb32 = xb[:, 0].astype(jnp.float32)
    a, b = _gates(p, cfg, xb32)
    h = a * state["h"] + b
    h = constrain(h, "batch", "d_rnn")
    gate = jax.nn.gelu(
        jnp.einsum("bld,dr->blr", x, p["w_gate"]).astype(jnp.float32),
        approximate=True)[:, 0]
    y = jnp.einsum("br,rd->bd", (h * gate).astype(x.dtype), p["w_out"])
    return constrain(y[:, None], "batch", "seq", "d_model"), \
        {"h": h, "conv": conv_state}


def rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    r = cfg.rglru.d_rnn or cfg.d_model
    w = cfg.rglru.conv_width
    return {"h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, w - 1, r), dtype)}


def rglru_state_axes():
    return {"h": ("batch", "d_rnn"), "conv": ("batch", "conv_w", "d_rnn")}
