"""Encoder-decoder wrapper (seamless-m4t): bidirectional encoder over stub
audio-frame embeddings + causal decoder with cross-attention."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


def encoder_config(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, num_layers=cfg.enc_layers,
                               is_encdec=False, moe=None)


def encdec_spec(cfg: ModelConfig):
    enc = lm.model_spec(encoder_config(cfg))
    enc.pop("embed")
    dec = lm.model_spec(cfg, cross=True)
    return {"encoder": enc, "decoder": dec}


def train_logits(params, cfg: ModelConfig, frames, dec_tokens,
                 chunk: int = 1024):
    enc_cfg = encoder_config(cfg)
    enc_out = lm.encode(params["encoder"], enc_cfg, frames, chunk=chunk)
    B, Se = enc_out.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    logits, _ = lm.forward(params["decoder"], cfg, mode="train",
                           tokens=dec_tokens, enc_out=enc_out,
                           enc_positions=enc_pos, chunk=chunk)
    return logits


def prefill(params, cfg: ModelConfig, frames, dec_tokens, chunk: int = 1024,
            cache_len=None):
    enc_cfg = encoder_config(cfg)
    enc_out = lm.encode(params["encoder"], enc_cfg, frames, chunk=chunk)
    B, Se = enc_out.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    logits, cache = lm.forward(params["decoder"], cfg, mode="prefill",
                               tokens=dec_tokens, enc_out=enc_out,
                               enc_positions=enc_pos, chunk=chunk,
                               cache_len=cache_len)
    return logits, cache


def decode(params, cfg: ModelConfig, cache, tokens, cur_index):
    return lm.forward(params["decoder"], cfg, mode="decode", tokens=tokens,
                      cache=cache, cur_index=cur_index)
