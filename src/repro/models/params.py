"""Spec-first parameters.

Models are described as pytrees of :class:`ParamSpec` (shape, dtype, logical
sharding axes, initializer). The same spec tree serves three purposes:

* ``materialize(spec, rng)``      -> real arrays (smoke tests, examples)
* ``abstract(spec)``              -> ShapeDtypeStructs (dry-run, AOT lowering)
* ``shardings(spec, mesh, rules)``-> NamedShardings for jit in_shardings

This guarantees the dry-run lowers exactly what the runnable code runs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                  # logical axis name (or None) per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"         # normal|zeros|ones|embed
    scale: float = 1.0           # stddev multiplier / fan-in override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _spec_leaves(tree):
    return jax.tree.leaves(tree, is_leaf=is_spec)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def stack_specs(tree, n: int, axis_name=None):
    """Add a leading stacked-layer dim of size ``n`` to every spec."""
    def add(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + tuple(s.shape), (axis_name,) + tuple(s.axes),
                         s.dtype, s.init, s.scale)
    return tree_map_specs(add, tree)


def abstract(tree):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(tuple(s.shape), s.dtype), tree)


def materialize(tree, rng: jax.Array):
    """Initialize real parameter arrays from a spec tree."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    rngs = jax.random.split(rng, max(len(leaves), 1))
    out = []
    for spec, key in zip(leaves, rngs):
        shape = tuple(spec.shape)
        if spec.init == "zeros":
            arr = jnp.zeros(shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(shape, spec.dtype)
        elif spec.init == "embed":
            arr = (jax.random.normal(key, shape, jnp.float32) * spec.scale
                   ).astype(spec.dtype)
        else:  # truncated-normal with 1/sqrt(fan_in) scaling
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = spec.scale / math.sqrt(max(fan_in, 1))
            arr = (jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                               jnp.float32) * std
                   ).astype(spec.dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def logical_axes(tree):
    return tree_map_specs(lambda s: tuple(s.axes), tree)


def param_count(tree) -> int:
    return sum(int(np.prod(s.shape)) for s in _spec_leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in _spec_leaves(tree))
