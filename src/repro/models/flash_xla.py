"""Flash attention at the XLA level: chunked online-softmax forward +
custom_vjp backward that recomputes per-chunk scores.

Why it exists: a naive (Sq, Skv) score materialization is impossible at 32k
(17 GB/chip), and differentiating a chunked scan stores O(Sq x Skv) residuals
anyway. This implementation keeps residuals at O(S·d): (q, k, v, out, lse) —
the standard flash decomposition — expressed in pure XLA so the 512-device
dry-run lowers it. The Pallas kernel (repro.kernels.flash_attention) is the
TPU production path; this is the semantically identical fallback and the
kernel's oracle is checked against it.

Layout: q (B, Sq, K, G, D); k, v (B, Skv, K, D). K = kv heads, G = q-per-kv.
Positions are implicit (q token i at position i), matching train/prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _mask_chunk(sq: int, kpos, *, causal: bool, window: int):
    """Additive mask (Sq, C) for kv chunk with absolute positions kpos."""
    qpos = jnp.arange(sq)
    d = qpos[:, None] - kpos[None, :]
    m = (kpos >= 0)[None, :] | jnp.zeros((sq, 1), bool)
    if causal:
        m &= d >= 0
    if window:
        m &= d < window
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


def _pad_kv(k, v, chunk):
    skv = k.shape[1]
    kpos = jnp.arange(skv)
    if skv % chunk:
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
    return k, v, kpos


def _fwd_impl(q, k, v, *, causal, window, cap, chunk,
              axes=("batch", "kv_heads", "heads", "seq", "seq_kv")):
    B, Sq, K, G, D = q.shape
    skv0 = k.shape[1]
    chunk = min(chunk, skv0)
    k, v, kpos = _pad_kv(k, v, chunk)
    Skv = k.shape[1]
    nc = Skv // chunk
    scale = D ** -0.5
    qs = (q * scale).astype(q.dtype)

    kc = k.reshape(B, nc, chunk, K, D).swapaxes(0, 1)
    vc = v.reshape(B, nc, chunk, K, D).swapaxes(0, 1)
    pc = kpos.reshape(nc, chunk)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qs, k_i).astype(jnp.float32)
        if cap:
            s = cap * jnp.tanh(s / cap)
        s = s + _mask_chunk(Sq, p_i, causal=causal, window=window
                            )[None, None, None]
        s = constrain(s, *axes)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_i.dtype), v_i)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    lse = m + jnp.log(jnp.maximum(l, 1e-37))
    out = acc / jnp.maximum(l, 1e-37).transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype), lse


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, window: int, cap: float, chunk: int,
                axes=("batch", "kv_heads", "heads", "seq", "seq_kv")):
    @jax.custom_vjp
    def flash(q, k, v):
        out, _ = _fwd_impl(q, k, v, causal=causal, window=window, cap=cap,
                           chunk=chunk, axes=axes)
        return out

    def fwd(q, k, v):
        out, lse = _fwd_impl(q, k, v, causal=causal, window=window, cap=cap,
                             chunk=chunk, axes=axes)
        return out, (q, k, v, out, lse)

    def bwd(res, dout):
        q, k, v, out, lse = res
        B, Sq, K, G, D = q.shape
        skv0 = k.shape[1]
        ch = min(chunk, skv0)
        kp, vp, kpos = _pad_kv(k, v, ch)
        Skv = kp.shape[1]
        nc = Skv // ch
        scale = D ** -0.5
        qs = (q * scale).astype(q.dtype)
        # D_i = rowsum(dout * out): (B,K,G,Sq)
        delta = jnp.einsum("bqkgd,bqkgd->bkgq", dout.astype(jnp.float32),
                           out.astype(jnp.float32))

        kc = kp.reshape(B, nc, ch, K, D).swapaxes(0, 1)
        vc = vp.reshape(B, nc, ch, K, D).swapaxes(0, 1)
        pc = kpos.reshape(nc, ch)

        def body(dq, xs):
            k_i, v_i, p_i = xs
            s_pre = jnp.einsum("bqkgd,bskd->bkgqs", qs, k_i
                               ).astype(jnp.float32)
            if cap:
                t = jnp.tanh(s_pre / cap)
                s = cap * t
            else:
                s = s_pre
            s = s + _mask_chunk(Sq, p_i, causal=causal, window=window
                                )[None, None, None]
            s = constrain(s, *axes)
            p = jnp.exp(s - lse[..., None])          # (B,K,G,Sq,C)
            dv_i = jnp.einsum("bkgqs,bqkgd->bskd", p.astype(dout.dtype),
                              dout)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", dout, v_i
                            ).astype(jnp.float32)
            ds = p * (dp - delta[..., None])
            if cap:
                ds = ds * (1.0 - t * t)
            ds = constrain(ds, *axes)
            ds = ds.astype(q.dtype)
            dq_i = jnp.einsum("bkgqs,bskd->bqkgd", ds, k_i)
            dk_i = jnp.einsum("bkgqs,bqkgd->bskd", ds, qs)
            return dq + dq_i.astype(jnp.float32), (dk_i, dv_i)

        dq0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
        dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, (kc, vc, pc))
        # dk_i was computed against qs = q*scale, so it is already scaled;
        # dq still needs the chain factor for s = (q*scale)·k.
        dk = dk_c.swapaxes(0, 1).reshape(B, Skv, K, D)[:, :skv0]
        dv = dv_c.swapaxes(0, 1).reshape(B, Skv, K, D)[:, :skv0]
        dq = (dq * scale).astype(q.dtype)
        return dq, dk.astype(k.dtype), dv.astype(v.dtype)

    flash.defvjp(fwd, bwd)
    return flash


def _seg_fwd(q, k, v, *, causal, window, cap, chunk, nseg):
    """Segmented context-parallel flash forward.

    k, v reshaped (B, nseg, S_loc, K, D) with the segment dim sharded over
    the model axis: every partial-softmax update inside the chunk scan is
    segment-local (zero communication); the single cross-segment merge at
    the end is the only collective — one all-reduce per layer instead of
    one per KV chunk (EXPERIMENTS.md §Perf, context-attention iteration).
    """
    B, Sq, K, G, D = q.shape
    Skv = k.shape[1]
    s_loc = Skv // nseg
    ch = min(chunk, s_loc)
    # cap the live fp32 score block (B,1,K,G,Sq,ch) around ~1 GiB/device:
    # the segment dim shards over the mesh but the chunk width does not
    while ch > 16 and B * K * G * Sq * ch * 4 > 1.5e9 * nseg:
        ch //= 2
    assert s_loc % ch == 0, (s_loc, ch)
    nc = s_loc // ch
    scale = D ** -0.5
    qs = (q * scale).astype(q.dtype)
    kseg = constrain(k.reshape(B, nseg, s_loc, K, D),
                     "batch", "kv_seg", None, "kv_heads", "head_dim")
    vseg = constrain(v.reshape(B, nseg, s_loc, K, D),
                     "batch", "kv_seg", None, "kv_heads", "head_dim")
    kc = kseg.reshape(B, nseg, nc, ch, K, D).transpose(2, 0, 1, 3, 4, 5)
    vc = vseg.reshape(B, nseg, nc, ch, K, D).transpose(2, 0, 1, 3, 4, 5)
    qpos = jnp.arange(Sq)
    # absolute positions per (segment, chunk-step, in-chunk)
    segpos = (jnp.arange(nseg)[:, None] * s_loc)        # (nseg, 1)

    def body(carry, xs):
        m, l, acc = carry                    # (B,nseg,K,G,Sq) / (...,Sq,D)
        k_i, v_i, ci = xs                    # (B,nseg,ch,K,D), step index
        s = jnp.einsum("bqkgd,bEskd->bEkgqs", qs, k_i).astype(jnp.float32)
        if cap:
            s = cap * jnp.tanh(s / cap)
        kpos = segpos + ci * ch + jnp.arange(ch)[None, :]   # (nseg, ch)
        dpos = qpos[None, :, None] - kpos[:, None, :]       # (nseg,Sq,ch)
        mask = jnp.ones_like(dpos, bool)
        if causal:
            mask &= dpos >= 0
        if window:
            mask &= dpos < window
        s = s + jnp.where(mask, 0.0, NEG_INF
                          )[None, :, None, None].astype(jnp.float32)
        s = constrain(s, "batch", "kv_seg", "kv_heads", "heads", "seq",
                      "seq_kv")
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bEkgqs,bEskd->bEqkgd", p.astype(v_i.dtype), v_i)
        corr_t = corr.transpose(0, 1, 4, 2, 3)[..., None]
        # accumulate in the input dtype: per-segment accumulators are
        # (B,nseg,Sq,K,G,D)-sized — fp32 doubles a multi-GiB live buffer
        # for <=2 chunk-steps of accumulation per segment
        acc_new = (acc.astype(jnp.float32) * corr_t
                   + pv.astype(jnp.float32)).astype(acc.dtype)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nseg, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nseg, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, nseg, Sq, K, G, D), q.dtype)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nc)))
    lse = m + jnp.log(jnp.maximum(l, 1e-37))        # (B,nseg,K,G,Sq)
    # single cross-segment merge (the only collective)
    lse_tot = jax.nn.logsumexp(lse, axis=1)         # (B,K,G,Sq)
    w = jnp.exp(lse - lse_tot[:, None])             # (B,nseg,K,G,Sq)
    norm = (acc.astype(jnp.float32)
            / jnp.maximum(l, 1e-37).transpose(0, 1, 4, 2, 3)[..., None]
            ).astype(q.dtype)
    out = jnp.einsum("bEkgq,bEqkgd->bqkgd", w.astype(q.dtype), norm)
    return out.astype(q.dtype), lse_tot


@functools.lru_cache(maxsize=None)
def _make_seg_flash(causal: bool, window: int, cap: float, chunk: int,
                    nseg: int):
    @jax.custom_vjp
    def flash(q, k, v):
        out, _ = _seg_fwd(q, k, v, causal=causal, window=window, cap=cap,
                          chunk=chunk, nseg=nseg)
        return out

    def fwd(q, k, v):
        out, lse = _seg_fwd(q, k, v, causal=causal, window=window, cap=cap,
                            chunk=chunk, nseg=nseg)
        return out, (q, k, v, out, lse)

    def bwd(res, dout):
        q, k, v, out, lse = res
        B, Sq, K, G, D = q.shape
        Skv = k.shape[1]
        s_loc = Skv // nseg
        ch = min(chunk, s_loc)
        while ch > 16 and B * K * G * Sq * ch * 4 > 1.5e9 * nseg:
            ch //= 2
        nc = s_loc // ch
        scale = D ** -0.5
        qs = (q * scale).astype(q.dtype)
        delta = jnp.einsum("bqkgd,bqkgd->bkgq", dout.astype(jnp.float32),
                           out.astype(jnp.float32))
        kseg = constrain(k.reshape(B, nseg, s_loc, K, D),
                         "batch", "kv_seg", None, "kv_heads", "head_dim")
        vseg = constrain(v.reshape(B, nseg, s_loc, K, D),
                         "batch", "kv_seg", None, "kv_heads", "head_dim")
        kc = kseg.reshape(B, nseg, nc, ch, K, D).transpose(2, 0, 1, 3, 4, 5)
        vc = vseg.reshape(B, nseg, nc, ch, K, D).transpose(2, 0, 1, 3, 4, 5)
        qpos = jnp.arange(Sq)
        segpos = jnp.arange(nseg)[:, None] * s_loc

        def body(dq, xs):
            k_i, v_i, ci = xs
            s = jnp.einsum("bqkgd,bEskd->bEkgqs", qs, k_i
                           ).astype(jnp.float32)
            if cap:
                t = jnp.tanh(s / cap)
                s = cap * t
            kpos = segpos + ci * ch + jnp.arange(ch)[None, :]
            dpos = qpos[None, :, None] - kpos[:, None, :]
            mask = jnp.ones_like(dpos, bool)
            if causal:
                mask &= dpos >= 0
            if window:
                mask &= dpos < window
            s = s + jnp.where(mask, 0.0, NEG_INF
                              )[None, :, None, None].astype(jnp.float32)
            s = constrain(s, "batch", "kv_seg", "kv_heads", "heads", "seq",
                          "seq_kv")
            # lse (B,K,G,Sq) -> broadcast (B,1,K,G,Sq,1)
            p = jnp.exp(s - lse[:, None, :, :, :, None])
            dv_i = jnp.einsum("bEkgqs,bqkgd->bEskd", p.astype(dout.dtype),
                              dout)
            dp = jnp.einsum("bqkgd,bEskd->bEkgqs", dout, v_i
                            ).astype(jnp.float32)
            ds = p * (dp - delta[:, None, :, :, :, None])
            if cap:
                ds = ds * (1.0 - t * t)
            ds = constrain(ds, "batch", "kv_seg", "kv_heads", "heads",
                           "seq", "seq_kv").astype(q.dtype)
            dq_i = jnp.einsum("bEkgqs,bEskd->bqkgd", ds, k_i)
            dk_i = jnp.einsum("bEkgqs,bqkgd->bEskd", ds, qs)
            return dq + dq_i.astype(jnp.float32), (dk_i, dv_i)

        dq0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
        dq, (dk_c, dv_c) = jax.lax.scan(body, dq0,
                                        (kc, vc, jnp.arange(nc)))
        # (nc,B,nseg,ch,K,D) -> (B, nseg*nc*ch = Skv, K, D)
        dk = dk_c.transpose(1, 2, 0, 3, 4, 5).reshape(B, Skv, K, D)
        dv = dv_c.transpose(1, 2, 0, 3, 4, 5).reshape(B, Skv, K, D)
        dq = (dq * scale).astype(q.dtype)
        return dq, dk.astype(k.dtype), dv.astype(v.dtype)

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention_xla(q, k, v, *, causal: bool, window: int = 0,
                        cap: float = 0.0, chunk: int = 1024,
                        kv_dim_is_heads: bool = False, segments: int = 0):
    """q (B,Sq,K,G,D); k,v (B,Skv,K,D) -> (B,Sq,K,G,D).

    kv_dim_is_heads: the K dim holds pre-expanded full q-heads (GQA expand
    path) — sharding labels swap so the head shards land on the right dim.
    segments > 1: combine-once context-parallel path (segment dim sharded
    over the model axis; one merge collective per call).
    """
    Skv = k.shape[1]
    if segments > 1 and Skv % segments == 0 and Skv // segments >= 16:
        return _make_seg_flash(bool(causal), int(window), float(cap),
                               int(chunk), int(segments))(q, k, v)
    axes = (("batch", "heads", "kv_heads", "seq", "seq_kv")
            if kv_dim_is_heads else
            ("batch", "kv_heads", "heads", "seq", "seq_kv"))
    return _make_flash(bool(causal), int(window), float(cap),
                       int(chunk), axes)(q, k, v)
