"""Compact ResNet-50 (inference) — the paper's own evaluation model family.

Used by the paper-faithful serving benchmarks (Fig 5/6: 15–3,600 ResNet50
copies on one worker). Inference-mode batchnorm (folded scale/bias).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec

STAGES = (3, 4, 6, 3)
WIDTHS = (64, 128, 256, 512)


def _conv_spec(cin, cout, k):
    return ParamSpec((k, k, cin, cout), (None, None, None, None))


def _bn_spec(c):
    return {"scale": ParamSpec((c,), (None,), init="ones"),
            "bias": ParamSpec((c,), (None,), init="zeros")}


def _bottleneck_spec(cin, width, stride):
    cout = width * 4
    s = {
        "conv1": _conv_spec(cin, width, 1), "bn1": _bn_spec(width),
        "conv2": _conv_spec(width, width, 3), "bn2": _bn_spec(width),
        "conv3": _conv_spec(width, cout, 1), "bn3": _bn_spec(cout),
    }
    if stride != 1 or cin != cout:
        s["proj"] = _conv_spec(cin, cout, 1)
        s["bn_proj"] = _bn_spec(cout)
    return s


def resnet50_spec(num_classes: int = 1000, scale: int = 1):
    """scale>1 shrinks widths (for fast smoke/serving tests)."""
    widths = tuple(max(8, w // scale) for w in WIDTHS)
    spec = {"stem": _conv_spec(3, widths[0], 7), "bn_stem": _bn_spec(widths[0])}
    cin = widths[0]
    for si, (n, w) in enumerate(zip(STAGES, widths)):
        blocks = []
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            blocks.append(_bottleneck_spec(cin, w, stride))
            cin = w * 4
        spec[f"stage{si}"] = tuple(blocks)
    spec["head"] = ParamSpec((cin, num_classes), (None, None))
    return spec


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(p, x):
    return x * p["scale"] + p["bias"]


def _bottleneck(p, x, stride):
    r = x
    y = jax.nn.relu(_bn(p["bn1"], _conv(x, p["conv1"])))
    y = jax.nn.relu(_bn(p["bn2"], _conv(y, p["conv2"], stride)))
    y = _bn(p["bn3"], _conv(y, p["conv3"]))
    if "proj" in p:
        r = _bn(p["bn_proj"], _conv(x, p["proj"], stride))
    return jax.nn.relu(y + r)


def resnet50_forward(params, x):
    """x (B, H, W, 3) -> logits (B, num_classes)."""
    x = x.astype(params["stem"].dtype)
    y = jax.nn.relu(_bn(params["bn_stem"], _conv(x, params["stem"], 2)))
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for si in range(len(STAGES)):
        for bi, bp in enumerate(params[f"stage{si}"]):
            stride = 2 if (bi == 0 and si > 0) else 1
            y = _bottleneck(bp, y, stride)
    y = y.mean(axis=(1, 2))
    return y @ params["head"]
