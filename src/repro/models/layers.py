"""Shared layers: norms, MLPs, rotary embeddings, token embedding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamSpec


# ---------------------------------------------------------------- norms

def rmsnorm_spec(d: int):
    return {"scale": ParamSpec((d,), ("d_model",), init="zeros")}


def rmsnorm(p, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale): zero-init = identity
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def layernorm_spec(d: int):
    return {"scale": ParamSpec((d,), ("d_model",), init="zeros"),
            "bias": ParamSpec((d,), ("d_model",), init="zeros")}


def layernorm(p, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + p["scale"].astype(jnp.float32)) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------- MLP

def mlp_spec(cfg: ModelConfig, d_ff: int = 0):
    d, ff = cfg.d_model, (d_ff or cfg.d_ff)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": ParamSpec((d, ff), ("d_model", "d_ff")),
            "w_in": ParamSpec((d, ff), ("d_model", "d_ff")),
            "w_out": ParamSpec((ff, d), ("d_ff", "d_model")),
        }
    return {  # standard gelu MLP (starcoder2-style)
        "w_in": ParamSpec((d, ff), ("d_model", "d_ff")),
        "b_in": ParamSpec((ff,), ("d_ff",), init="zeros"),
        "w_out": ParamSpec((ff, d), ("d_ff", "d_model")),
        "b_out": ParamSpec((d,), ("d_model",), init="zeros"),
    }


def mlp(p, cfg: ModelConfig, x):
    if "w_gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = jnp.einsum("...d,df->...f", x, p["w_in"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jnp.einsum("...d,df->...f", x, p["w_in"]) + p["b_in"]
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    h = constrain(h, "batch", "seq", "d_ff")
    y = jnp.einsum("...f,fd->...d", h, p["w_out"])
    if "b_out" in p:
        y = y + p["b_out"]
    return y


# ---------------------------------------------------------------- rotary

def rope(x, positions, theta: float):
    """Apply rotary embedding.

    x: (..., seq, heads, head_dim); positions: (..., seq) int32.
    """
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq     # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x32_1 * cos - x32_2 * sin, x32_2 * cos + x32_1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- embedding

def embed_spec(cfg: ModelConfig):
    s = {"embedding": ParamSpec((cfg.vocab_padded, cfg.d_model),
                                ("vocab", "d_model"), init="embed",
                                scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_padded),
                                 ("d_model", "vocab"))
    return s


def embed(p, cfg: ModelConfig, tokens):
    x = jnp.take(p["embedding"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return constrain(x, "batch", "seq", "d_model")


def unembed(p, cfg: ModelConfig, x):
    table = p["embedding"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, table).astype(jnp.float32)
    if cfg.final_softcap:
        c = cfg.final_softcap
        logits = c * jnp.tanh(logits / c)
    # mask padded vocab entries
    if cfg.vocab_padded != cfg.vocab_size:
        neg = jnp.finfo(jnp.float32).min
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(mask, logits, neg)
    return constrain(logits, "batch", "seq", "vocab")


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x
