"""Mixture-of-Experts with explicit expert parallelism.

Production path (mesh with a ``data`` axis and experts divisible): a
``shard_map`` over the whole mesh — sort-based capacity dispatch, all_to_all
token exchange over the data axis (expert parallelism), per-expert FFN with
the expert d_ff sharded over the model axis (psum to combine), all_to_all
back, weighted combine. Tokens over capacity are dropped (Switch-style,
capacity_factor bounds the drop rate).

Fallback path (single device / smoke configs): dense compute of every expert
on every token, masked by router weights — semantically the no-drop reference.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, current_mesh_rules, spec_for
from repro.models.params import ParamSpec


def moe_spec(cfg: ModelConfig):
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    s = {
        "router": ParamSpec((d, e), ("d_model", None), dtype=jnp.float32),
        "w_gate": ParamSpec((e, d, f), ("experts", "d_model", "expert_ff")),
        "w_in": ParamSpec((e, d, f), ("experts", "d_model", "expert_ff")),
        "w_out": ParamSpec((e, f, d), ("experts", "expert_ff", "d_model")),
    }
    return s


def _router(p, cfg: ModelConfig, x):
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i, logits


def _expert_ffn(xs, w_gate, w_in, w_out):
    """xs (E, C, d); weights (E, d, f)/(E, f, d). Returns (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate)
    h = jnp.einsum("ecd,edf->ecf", xs, w_in)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype) * h
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _dispatch_tables(top_i, top_p, num_experts: int, capacity: int):
    """Sort-based capacity dispatch tables.

    Returns (token_for_slot (E*C,), weight_for_slot (E*C,), valid (E*C,)).
    """
    n, k = top_i.shape
    flat_e = top_i.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), k)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)                      # stable
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    starts = jnp.searchsorted(se, jnp.arange(num_experts), side="left")
    rank = jnp.arange(n * k) - starts[se]
    keep = rank < capacity
    slot = jnp.where(keep, se * capacity + rank, num_experts * capacity)
    token_for_slot = jnp.full((num_experts * capacity,), -1, jnp.int32)
    token_for_slot = token_for_slot.at[slot].set(st.astype(jnp.int32),
                                                 mode="drop")
    weight_for_slot = jnp.zeros((num_experts * capacity,), jnp.float32)
    weight_for_slot = weight_for_slot.at[slot].set(sp, mode="drop")
    return token_for_slot, weight_for_slot


def _moe_local(x_flat, p, cfg: ModelConfig, capacity: int, data_axis,
               model_axis):
    """Body run per-device inside shard_map. x_flat (N_loc, d)."""
    m = cfg.moe
    e = m.num_experts
    top_p, top_i, _ = _router(p, cfg, x_flat)
    tok, wgt = _dispatch_tables(top_i, top_p, e, capacity)
    valid = tok >= 0
    xs = x_flat[jnp.clip(tok, 0)] * valid[:, None].astype(x_flat.dtype)
    xs = xs.reshape(e, capacity, -1)

    if data_axis is not None:
        n_data = jax.lax.axis_size(data_axis)
        # (E, C, d) -> (E_loc, n_data*C, d): every device keeps its experts.
        xs = jax.lax.all_to_all(xs, data_axis, split_axis=0, concat_axis=1,
                                tiled=True)
    ys = _expert_ffn(xs, p["w_gate"], p["w_in"], p["w_out"])
    if model_axis is not None:
        ys = jax.lax.psum(ys, model_axis)            # combine expert-ff TP
    if data_axis is not None:
        ys = jax.lax.all_to_all(ys, data_axis, split_axis=1, concat_axis=0,
                                tiled=True)
    ys = ys.reshape(e * capacity, -1)
    out = jnp.zeros_like(x_flat, dtype=jnp.float32)
    out = out.at[jnp.clip(tok, 0)].add(
        ys.astype(jnp.float32) * (wgt * valid)[:, None], mode="drop")
    return out.astype(x_flat.dtype)


def moe_apply(p, cfg: ModelConfig, x):
    """x (B, S, d) -> (B, S, d)."""
    m = cfg.moe
    mesh, rules = current_mesh_rules()
    B, S, d = x.shape

    ep_axes = tuple(a for a in ("pod", "data") if
                    (mesh is not None and a in mesh.axis_names))
    n_ep = 1
    for a in ep_axes:
        n_ep *= mesh.shape[a]
    use_ep = (
        mesh is not None and ep_axes
        and m.num_experts % n_ep == 0
        and rules is not None
    )
    if not use_ep:
        # Dense reference: every expert on every token (smoke/tests only).
        top_p, top_i, _ = _router(p, cfg, x)
        full = jnp.zeros((B, S, m.num_experts), jnp.float32)
        full = full.at[
            jnp.arange(B)[:, None, None],
            jnp.arange(S)[None, :, None],
            top_i,
        ].set(top_p)
        g = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
        h = jnp.einsum("bsd,edf->bsef", x, p["w_in"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
        y = jnp.einsum("bsef,efd->bsed", h, p["w_out"])
        return jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32), full
                          ).astype(x.dtype)

    # ---- expert-parallel shard_map path ----
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = math.prod(mesh.shape[a] for a in dp)
    has_model = "model" in mesh.axis_names

    n_local = (B // n_dp if B % n_dp == 0 else B) * S
    # process tokens in bounded chunks: the (E, C, d) dispatch buffers scale
    # with tokens-per-chunk, not with the whole 32k prefill (§Perf)
    token_chunk = 4096
    n_chunks = max(1, -(-n_local // token_chunk))
    while n_local % n_chunks:
        n_chunks -= 1
    chunk_tokens = n_local // n_chunks
    capacity = max(
        m.min_capacity,
        int(math.ceil(chunk_tokens * m.top_k / m.num_experts
                      * m.capacity_factor)),
    )

    batch_spec = spec_for(rules, ("batch",), (B,))
    x_spec = P(*(tuple(batch_spec) + (None, None)))
    w_ep = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    in_specs = (
        x_spec,
        {
            "router": P(None, None),
            "w_gate": P(w_ep, None, "model"),   # (E, d, f)
            "w_in": P(w_ep, None, "model"),     # (E, d, f)
            "w_out": P(w_ep, "model", None),    # (E, f, d)
        },
    )

    def body(xb, pl):
        xf = xb.reshape(-1, xb.shape[-1])
        axis = ep_axes if len(ep_axes) > 1 else ep_axes[0]

        if n_chunks == 1:
            out = _moe_local(xf, pl, cfg, capacity, data_axis=axis,
                             model_axis="model" if has_model else None)
        else:
            xc = xf.reshape(n_chunks, chunk_tokens, xf.shape[-1])

            def chunk_body(_, xi):
                return None, _moe_local(
                    xi, pl, cfg, capacity, data_axis=axis,
                    model_axis="model" if has_model else None)

            _, out = jax.lax.scan(chunk_body, None, xc)
            out = out.reshape(xf.shape)
        return out.reshape(xb.shape)

    smapped = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                            out_specs=x_spec, check_vma=False)
    # remat the shard_map as a unit: jax.checkpoint cannot see inside it, so
    # without this its per-layer residuals (dispatch buffers, fp32 combine)
    # are SAVED across the layer scan — measured 25 GiB/device on the qwen3
    # train cell (EXPERIMENTS.md §Perf).
    y = jax.checkpoint(smapped)(x, p)
    return constrain(y, "batch", "seq", "d_model")
