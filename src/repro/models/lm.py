"""Decoder-only language model assembled from pattern blocks.

A model is ``num_layers`` blocks laid out as a repeating ``cfg.pattern``
(e.g. ``("local","global")`` for gemma2, ``("rec","rec","attn")`` for
recurrentgemma, ``("ssm",)`` for mamba2). The repeating part is stacked and
driven by ``lax.scan`` (keeps HLO size O(pattern) instead of O(layers) —
essential for 94-layer dry-runs); leftover layers run unrolled.

Three modes share one block implementation:
  * ``train``   — full attention, no cache, remat over the scan body
  * ``prefill`` — full attention, returns a decode-ready cache
  * ``decode``  — one token against the cache (the serving hot path)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import params as pspec
from repro.models.attention import (attend_decode, attend_full, attn_spec,
                                    cache_axes, make_cache,
                                    prefill_into_cache)
from repro.models.layers import (embed, embed_spec, mlp, mlp_spec, rmsnorm,
                                 rmsnorm_spec, unembed)
from repro.models.moe import moe_apply, moe_spec
from repro.models.rglru import (rglru_decode, rglru_full, rglru_spec,
                                rglru_state, rglru_state_axes)
from repro.models.ssm import (mamba_decode, mamba_full, mamba_spec,
                              mamba_state, mamba_state_axes)

ATTN_KINDS = ("attn", "local")


# ------------------------------------------------------------------ specs

def block_spec(cfg: ModelConfig, kind: str, cross: bool = False):
    d = cfg.d_model
    s = {"ln1": rmsnorm_spec(d)}
    if kind in ATTN_KINDS:
        s["attn"] = attn_spec(cfg)
        if cross:
            s["ln_x"] = rmsnorm_spec(d)
            s["cross"] = attn_spec(cfg, cross=True)
    elif kind == "ssm":
        s["ssm"] = mamba_spec(cfg)
    elif kind == "rec":
        s["rec"] = rglru_spec(cfg)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        s["ln1_post"] = rmsnorm_spec(d)
    if cfg.moe is not None:
        s["ln2"] = rmsnorm_spec(d)
        s["moe"] = moe_spec(cfg)
        if cfg.moe.shared_expert:
            s["shared"] = mlp_spec(cfg, cfg.moe.d_ff_expert)
        if cfg.post_norms:
            s["ln2_post"] = rmsnorm_spec(d)
    elif cfg.mlp != "none":
        s["ln2"] = rmsnorm_spec(d)
        s["mlp"] = mlp_spec(cfg)
        if cfg.post_norms:
            s["ln2_post"] = rmsnorm_spec(d)
    return s


def model_spec(cfg: ModelConfig, cross: bool = False):
    pattern, n_groups, leftover = cfg.pattern_split()
    return {
        "embed": embed_spec(cfg),
        "stack": tuple(
            pspec.stack_specs(block_spec(cfg, kind, cross), n_groups,
                              "layers")
            for kind in pattern),
        "leftover": tuple(block_spec(cfg, kind, cross) for kind in leftover),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }


# ------------------------------------------------------------------ caches

def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 dtype, cross_len: int = 0):
    if kind in ATTN_KINDS:
        c = {"kv": make_cache(cfg, kind, batch, max_len, dtype)}
        if cross_len:
            c["cross"] = make_cache(cfg, "attn", batch, cross_len, dtype)
        return c
    if kind == "ssm":
        return {"state": mamba_state(cfg, batch, dtype)}
    if kind == "rec":
        return {"state": rglru_state(cfg, batch, dtype)}
    raise ValueError(kind)


def _block_cache_axes(cfg: ModelConfig, kind: str, cross_len: int = 0):
    if kind in ATTN_KINDS:
        c = {"kv": {"k": cache_axes(), "v": cache_axes()}}
        if cross_len:
            c["cross"] = {"k": cache_axes(), "v": cache_axes()}
        return c
    if kind == "ssm":
        return {"state": mamba_state_axes()}
    if kind == "rec":
        return {"state": rglru_state_axes()}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, cross_len: int = 0):
    pattern, n_groups, leftover = cfg.pattern_split()
    stack = tuple(
        jax.tree.map(
            lambda a: jnp.zeros((n_groups,) + a.shape, a.dtype),
            _block_cache(cfg, kind, batch, max_len, dtype, cross_len))
        for kind in pattern)
    left = tuple(_block_cache(cfg, kind, batch, max_len, dtype, cross_len)
                 for kind in leftover)
    return {"stack": stack, "leftover": left}


def cache_abstract(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16, cross_len: int = 0):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype, cross_len))


def cache_logical_axes(cfg: ModelConfig, cross_len: int = 0):
    """Pytree of logical-axis tuples matching init_cache structure."""
    pattern, n_groups, leftover = cfg.pattern_split()

    def is_axes(x):
        return isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)

    stack = tuple(
        jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                     _block_cache_axes(cfg, kind, cross_len),
                     is_leaf=is_axes)
        for kind in pattern)
    left = tuple(_block_cache_axes(cfg, kind, cross_len)
                 for kind in leftover)
    return {"stack": stack, "leftover": left}


# ------------------------------------------------------------------ blocks

def block_apply(p, cfg: ModelConfig, kind: str, x, *, mode: str,
                positions=None, cur_index=None, cache=None, enc_out=None,
                enc_positions=None, causal: bool = True, chunk: int = 1024,
                cache_len=None):
    """Apply one block. Returns (x, new_cache)."""
    eps = cfg.norm_eps
    h = rmsnorm(p["ln1"], x, eps)
    new_cache = {}
    if kind in ATTN_KINDS:
        if mode == "decode":
            y, kv = attend_decode(p["attn"], cfg, h, cache["kv"], cur_index,
                                  kind=kind)
            new_cache["kv"] = kv
        else:
            y, (k, v) = attend_full(p["attn"], cfg, h, kind=kind,
                                    positions=positions, causal=causal,
                                    chunk=chunk)
            if mode == "prefill":
                new_cache["kv"] = prefill_into_cache(
                    cfg, kind, k, v, max_len=cache_len or k.shape[1])
    elif kind == "ssm":
        if mode == "decode":
            y, st = mamba_decode(p["ssm"], cfg, h, cache["state"])
        else:
            y, st = mamba_full(p["ssm"], cfg, h)
        if mode != "train":
            new_cache["state"] = st
    elif kind == "rec":
        if mode == "decode":
            y, st = rglru_decode(p["rec"], cfg, h, cache["state"])
        else:
            y, st = rglru_full(p["rec"], cfg, h)
        if mode != "train":
            new_cache["state"] = st
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        y = rmsnorm(p["ln1_post"], y, eps)
    x = x + y

    if "cross" in p:
        h = rmsnorm(p["ln_x"], x, eps)
        if mode == "decode":
            y, cc = attend_decode(p["cross"], cfg, h, cache["cross"],
                                  cur_index, kind="attn", cross=True)
            new_cache["cross"] = cc
        else:
            y, (ck, cv) = attend_full(p["cross"], cfg, h, kind="attn",
                                      positions=positions, x_kv=enc_out,
                                      kv_positions=enc_positions, cross=True,
                                      chunk=chunk)
            if mode == "prefill":
                new_cache["cross"] = {"k": ck, "v": cv}
        x = x + y

    if "moe" in p:
        h = rmsnorm(p["ln2"], x, eps)
        y = moe_apply(p["moe"], cfg, h)
        if "shared" in p:
            y = y + mlp(p["shared"], cfg, h)
        if cfg.post_norms:
            y = rmsnorm(p["ln2_post"], y, eps)
        x = x + y
    elif "mlp" in p:
        h = rmsnorm(p["ln2"], x, eps)
        y = mlp(p["mlp"], cfg, h)
        if cfg.post_norms:
            y = rmsnorm(p["ln2_post"], y, eps)
        x = x + y
    # residual stream between blocks: optionally sequence-sharded over the
    # model axis (Megatron-SP) so scan-carry checkpoints shard 16x
    return constrain(x, "batch", "seq_act", "d_model"), new_cache


# ------------------------------------------------------------------ forward

def _run_stack(params, cfg: ModelConfig, x, *, mode, positions=None,
               cur_index=None, cache=None, enc_out=None, enc_positions=None,
               causal=True, chunk=1024, cache_len=None):
    pattern, n_groups, leftover = cfg.pattern_split()
    want_cache = mode != "train"          # produce caches
    take_cache = mode == "decode"         # consume caches

    def group_body(h, xs):
        p_group = xs[0]
        c_group = xs[1] if take_cache else None
        new_caches = []
        for i, kind in enumerate(pattern):
            h, nc = block_apply(
                p_group[i], cfg, kind, h, mode=mode, positions=positions,
                cur_index=cur_index,
                cache=(c_group[i] if c_group is not None else None),
                enc_out=enc_out, enc_positions=enc_positions,
                causal=causal, chunk=chunk, cache_len=cache_len)
            new_caches.append(nc)
        return h, tuple(new_caches) if want_cache else None

    body = group_body
    if mode == "train" and cfg.remat:
        body = jax.checkpoint(group_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    if n_groups > 0:
        if take_cache:
            # Decode: the cache rides the scan CARRY and is updated in place
            # (dynamic_update_index_in_dim). XLA aliases carry buffers through
            # the loop — measured 17x lower temp memory than the xs/ys
            # formulation (EXPERIMENTS.md §Perf, decode-cache iteration).
            def decode_body(carry, xs):
                h, cstack = carry
                p_group, gi = xs
                new_stack = list(cstack)
                for i, kind in enumerate(pattern):
                    c_i = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, gi, 0, keepdims=False), cstack[i])
                    h, nc = block_apply(
                        p_group[i], cfg, kind, h, mode=mode,
                        positions=positions, cur_index=cur_index, cache=c_i,
                        enc_out=enc_out, enc_positions=enc_positions,
                        causal=causal, chunk=chunk, cache_len=cache_len)
                    new_stack[i] = jax.tree.map(
                        lambda a, n: jax.lax.dynamic_update_index_in_dim(
                            a, n.astype(a.dtype), gi, 0), cstack[i], nc)
                return (h, tuple(new_stack)), None

            (x, stack_caches), _ = jax.lax.scan(
                decode_body, (x, cache["stack"]),
                (params["stack"], jnp.arange(n_groups)))
        else:
            x, stack_caches = jax.lax.scan(body, x, (params["stack"],))
    else:
        stack_caches = tuple()

    left_caches = []
    for i, kind in enumerate(leftover):
        c = cache["leftover"][i] if take_cache and cache else None
        x, nc = block_apply(
            params["leftover"][i], cfg, kind, x, mode=mode,
            positions=positions, cur_index=cur_index, cache=c,
            enc_out=enc_out, enc_positions=enc_positions,
            causal=causal, chunk=chunk, cache_len=cache_len)
        left_caches.append(nc)

    new_cache = ({"stack": stack_caches, "leftover": tuple(left_caches)}
                 if want_cache else None)
    return x, new_cache


def forward(params, cfg: ModelConfig, *, mode: str, tokens=None, embeds=None,
            image_embeds=None, cache=None, cur_index=None, enc_out=None,
            enc_positions=None, causal: bool = True, chunk: int = 1024,
            cache_len=None):
    """Returns (logits, new_cache).

    * train:   logits over all positions, cache None
    * prefill: logits for the last position only, decode-ready cache
    * decode:  logits for the new token (B, 1, V), updated cache
    """
    if embeds is not None:
        x = constrain(embeds, "batch", "seq", "d_model")
    else:
        x = embed(params["embed"], cfg, tokens)
        if image_embeds is not None:
            img = image_embeds.astype(x.dtype)
            if cfg.scale_embed:
                img = img * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
            x = jnp.concatenate([img, x], axis=1)
            x = constrain(x, "batch", "seq", "d_model")
    B, S = x.shape[:2]

    if mode == "decode":
        positions = None
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    x, new_cache = _run_stack(
        params, cfg, x, mode=mode, positions=positions, cur_index=cur_index,
        cache=cache, enc_out=enc_out, enc_positions=enc_positions,
        causal=causal, chunk=chunk, cache_len=cache_len)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if mode == "prefill":
        x = x[:, -1:]
    logits = unembed(params["embed"], cfg, x)
    return logits, new_cache


def encode(params, cfg: ModelConfig, embeds, chunk: int = 1024):
    """Bidirectional encoder pass (enc-dec models): embeds (B,S,d) -> (B,S,d)."""
    x, _ = _run_stack(params, cfg, constrain(embeds, "batch", "seq", "d_model"),
                      mode="train", positions=jnp.broadcast_to(
                          jnp.arange(embeds.shape[1], dtype=jnp.int32),
                          embeds.shape[:2]),
                      causal=False, chunk=chunk)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


def greedy_sample(logits):
    """(B, 1, V) -> (B, 1) int32 next tokens."""
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
