"""Deterministic synthetic LM data pipeline.

Produces next-token-prediction batches from a seeded Markov token stream —
deterministic given (seed, step), so the pipeline is *stateless-resumable*:
restoring a checkpoint at step N reproduces exactly the batches the crashed
run would have seen (the fault-tolerance contract training relies on).

A background prefetch thread overlaps host batch synthesis with device
compute (double-buffering), mirroring a production input pipeline.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


class SyntheticLM:
    """Markov-chain token stream with a learnable structure (so training
    loss visibly decreases): P(next | cur) concentrated on a few successors.
    """

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                 branching: int = 4):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.vocab = cfg.vocab_size
        rng = np.random.default_rng(seed)
        self.succ = rng.integers(0, self.vocab,
                                 size=(min(self.vocab, 4096), branching),
                                 dtype=np.int32)

    def _tokens(self, step: int, batch: int, length: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        out = np.empty((batch, length + 1), np.int32)
        cur = rng.integers(0, self.vocab, size=batch, dtype=np.int32)
        out[:, 0] = cur
        choices = rng.integers(0, self.succ.shape[1],
                               size=(batch, length), dtype=np.int32)
        for t in range(length):
            cur = self.succ[cur % self.succ.shape[0], choices[:, t]]
            out[:, t + 1] = cur
        return out

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg, shape = self.cfg, self.shape
        B, S = shape.global_batch, shape.seq_len
        if cfg.is_encdec:
            rng = np.random.default_rng((self.seed, step, 7))
            toks = self._tokens(step, B, S)
            return {
                "frames": rng.standard_normal((B, S, cfg.d_model)
                                              ).astype(np.float32) * 0.02,
                "tokens": toks[:, :-1],
                "targets": toks[:, 1:],
            }
        if cfg.modality == "image_patches":
            st = S - cfg.img_tokens
            rng = np.random.default_rng((self.seed, step, 7))
            toks = self._tokens(step, B, st)
            return {
                "tokens": toks[:, :-1],
                "image_embeds": rng.standard_normal(
                    (B, cfg.img_tokens, cfg.d_model)).astype(np.float32)
                * 0.02,
                "targets": toks[:, 1:],
            }
        toks = self._tokens(step, B, S)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class Prefetcher:
    """Double-buffered background prefetch; resumable via start_step."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.step = start_step
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            b = self.source.batch(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
