"""Flash attention Pallas TPU kernel.

Layout: q/k/v flattened to (BH, S, D) — the ops.py wrapper handles GQA
expansion and head flattening. Grid (BH, nq, nk), kv innermost; running
(m, l, acc) in VMEM scratch; out written on the last kv block.

Block shapes are MXU-aligned (multiples of 128 on the lane dim; D is the
head dim, 64..256 for all assigned archs). Causal blocks strictly above the
diagonal are skipped with pl.when (real compute savings on TPU, where the
grid is executed sequentially per core).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, cap: float, kv_len: int,
            block_q: int, block_k: int, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    first_q = i * block_q
    first_k = j * block_k
    # causal: whole block above the diagonal contributes nothing
    live = (not causal) or (first_k <= first_q + block_q - 1)
    # sliding window: whole block left of every query's window is dead
    if window:
        live_w = first_q - (first_k + block_k - 1) < window
    else:
        live_w = True

    @pl.when(jnp.logical_and(live, live_w))
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale         # (bq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if cap:
            s = cap * jnp.tanh(s / cap)
        qpos = first_q + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = first_k + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == nk - 1)
    def _out():
        denom = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         cap: float = 0.0, block_q: int = 128,
                         block_k: int = 128, interpret: bool = True):
    """q (BH, Sq, D); k, v (BH, Skv, D) -> (BH, Sq, D)."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k

    kern = functools.partial(
        _kernel, causal=causal, window=window, cap=cap, kv_len=Skv,
        block_q=block_q, block_k=block_k, scale=D ** -0.5)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, D), q.dtype),
        grid=(BH, Sq_p // block_q, Skv_p // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
