"""Mamba2 SSD chunk-scan Pallas TPU kernel.

Per (batch, head): intra-chunk attention-like einsums on the MXU (QxQ decay-
masked score matrix) + a sequential inter-chunk state recurrence carried in
VMEM scratch — the TPU-native shape of the SSD algorithm (chunk dims are
MXU-aligned; the recurrence touches only the (P, N) state, which never
leaves VMEM between chunks).

Layout: x (BH, L, P); dt (BH, L); a (BH,); bmat/cmat (BH, L, N).
Outputs: y (BH, L, P), final state (BH, P, N) fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref,
            state_ref, *, chunk: int):
    c_idx = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0].astype(jnp.float32)                      # scalar (negative)
    dt = dt_ref[0].astype(jnp.float32)                    # (Q,)
    x = x_ref[0].astype(jnp.float32)                      # (Q, P)
    bm = b_ref[0].astype(jnp.float32)                     # (Q, N)
    cm = c_ref[0].astype(jnp.float32)                     # (Q, N)

    dA = dt * a                                           # (Q,) log-decay
    cum = jnp.cumsum(dA)                                  # (Q,)
    xdt = x * dt[:, None]                                 # (Q, P)

    # intra-chunk: scores (Q, Q) with decay mask
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    rel = cum[:, None] - cum[None, :]                     # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (cum.shape[0],) * 2, 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (cum.shape[0],) * 2, 1)
    lmat = jnp.where(qi >= ki, jnp.exp(rel), 0.0)
    w = scores * lmat
    y_intra = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the incoming state
    s_in = state_ref[...]                                 # (P, N)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, s_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (Q, P)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S' = T * S_in + sum_s to_end[s] * xdt[s] (x) b[s]
    to_end = jnp.exp(cum[-1] - cum)                       # (Q,)
    s_chunk = jax.lax.dot_general((xdt * to_end[:, None]), bm,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(cum[-1]) * s_in + s_chunk    # (P, N)

    @pl.when(c_idx == nc - 1)
    def _emit_state():
        s_out_ref[0] = state_ref[...]


def ssd_scan_bh(x, dt, a, bmat, cmat, *, chunk: int = 128,
                interpret: bool = True):
    """x (BH, L, P); dt (BH, L); a (BH,); bmat/cmat (BH, L, N)."""
    BH, L, P = x.shape
    N = bmat.shape[-1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:          # dt=0 on the tail: decay 1, zero input, state unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad

    kern = functools.partial(_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((BH, Lp, P), x.dtype),
                   jax.ShapeDtypeStruct((BH, P, N), jnp.float32)),
        grid=(BH, Lp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, P, N), lambda b, c: (b, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bmat, cmat)
    return y[:, :L], state
