"""Jit'd convenience wrappers over the Pallas kernels.

These accept model-layout tensors (B, S, H/K/G, D) and handle flattening,
GQA expansion, and (on CPU) interpret-mode execution. On TPU, pass
``interpret=False`` — the pallas_call lowers to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.flash_decode import flash_decode_bkgd
from repro.kernels.ssd_scan import ssd_scan_bh


@partial(jax.jit, static_argnames=("causal", "window", "cap", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                    block_q=128, block_k=128, interpret=True):
    """q (B,Sq,H,D); k,v (B,Skv,K,D) with H = K*G -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    if K != H:                       # GQA: expand kv heads
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               cap=cap, block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("window", "cap", "block_s", "interpret"))
def flash_decode(q, k, v, kpos, cur_index, *, window=0, cap=0.0,
                 block_s=256, interpret=True):
    """q (B,1,H,D); k,v (B,S,K,D); kpos (S,) -> (B,1,H,D)."""
    B, _, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.reshape(B, K, G, D).reshape(B * K, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    out = flash_decode_bkgd(qf, kf, vf, kpos, cur_index, window=window,
                            cap=cap, block_s=block_s, interpret=interpret)
    return out.reshape(B, K * G, D)[:, None]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, bmat, cmat, *, chunk=128, interpret=True):
    """Model layout: x (B,L,H,P); dt (B,L,H); a (H,); b/c (B,L,N).

    Returns (y (B,L,H,P), state (B,H,P,N))."""
    B, L, H, P = x.shape
    N = bmat.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, L, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, L)
    af = jnp.tile(a, B)
    bf = jnp.broadcast_to(bmat[:, None], (B, H, L, N)).reshape(B * H, L, N)
    cf = jnp.broadcast_to(cmat[:, None], (B, H, L, N)).reshape(B * H, L, N)
    y, state = ssd_scan_bh(xf, dtf, af, bf, cf, chunk=chunk,
                           interpret=interpret)
    return (y.reshape(B, H, L, P).transpose(0, 2, 1, 3),
            state.reshape(B, H, P, N))
