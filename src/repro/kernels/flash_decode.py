"""Flash-decode Pallas TPU kernel: one new token against a KV cache.

The serving hot path (DECODE actions). Memory-bound: each step streams the
cache HBM->VMEM once; the kernel's job is to keep that stream dense and fuse
the online softmax so nothing round-trips. Supports ring-buffer caches via an
explicit per-slot absolute-position array `kpos` (positions < 0 = invalid),
a current index, and a sliding window — exactly the masking semantics of
`repro.models.attention.attend_decode` (the oracle).

Layout: q (BK, G, D); k, v (BK, S, D); kpos (S,). BK = batch x kv-heads,
G = q-heads-per-kv-head (ops.py reshapes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(cur_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: int, cap: float,
            block_s: int, scale: float):
    j = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = cur_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale             # (G, D)
    k = k_ref[0].astype(jnp.float32)                     # (bs, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    if cap:
        s = cap * jnp.tanh(s / cap)
    kpos = kpos_ref[...]                                 # (bs,)
    valid = (kpos >= 0) & (kpos <= cur)
    if window:
        valid &= kpos > cur - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == ns - 1)
    def _out():
        denom = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode_bkgd(q, k, v, kpos, cur_index, *, window: int = 0,
                      cap: float = 0.0, block_s: int = 256,
                      interpret: bool = True):
    """q (BK, G, D); k, v (BK, S, D); kpos (S,) -> (BK, G, D)."""
    BK, G, D = q.shape
    S = k.shape[1]
    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
    Sp = S + pad
    cur = jnp.asarray(cur_index, jnp.int32).reshape(1)

    kern = functools.partial(_kernel, window=window, cap=cap,
                             block_s=block_s, scale=D ** -0.5)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((BK, G, D), q.dtype),
        grid=(BK, Sp // block_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_s, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_s, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((block_s,), lambda b, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(cur, q, k, v, kpos)
