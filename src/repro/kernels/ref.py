"""Pure-jnp oracles for every kernel (the ground truth tests compare to)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import NEG_INF
from repro.models.ssm import ssd_reference  # noqa: F401  (ssd oracle)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        cap: float = 0.0):
    """q (BH, Sq, D); k, v (BH, Skv, D) — full-scores reference."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * D ** -0.5,
                   k.astype(jnp.float32))
    if cap:
        s = cap * jnp.tanh(s / cap)
    Sq, Skv = q.shape[1], k.shape[1]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def flash_decode_ref(q, k, v, kpos, cur_index, *, window: int = 0,
                     cap: float = 0.0):
    """q (BK, G, D); k, v (BK, S, D); kpos (S,)."""
    D = q.shape[-1]
    s = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32) * D ** -0.5,
                   k.astype(jnp.float32))
    if cap:
        s = cap * jnp.tanh(s / cap)
    valid = (kpos >= 0) & (kpos <= cur_index)
    if window:
        valid &= kpos > cur_index - window
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bgs,bsd->bgd", p.astype(v.dtype), v)
