"""Pallas TPU kernels for the serving hot paths.

* flash_attention — prefill/train attention (causal + sliding-window +
  logit softcap), online softmax, VMEM-tiled via BlockSpec.
* flash_decode — one-token decode against a (possibly ring) KV cache,
  blocked over sequence with an online-softmax accumulator.
* ssd_scan — Mamba2 state-space-duality chunk scan (intra-chunk einsums +
  sequential inter-chunk state carry in VMEM scratch).

Each kernel ships with a pure-jnp oracle in ref.py and a jit'd wrapper in
ops.py; tests sweep shapes/dtypes in interpret mode (this container is
CPU-only — TPU is the compile target, interpret mode validates semantics).
"""
