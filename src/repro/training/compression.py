"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized all-reduce: gradients are quantized to int8 with a
per-block fp32 scale before the data-parallel reduction, and the
quantization error is carried to the next step (error feedback keeps the
method unbiased in the long run — Seide et al. / EF-SGD).

Under pjit, expressing the reduction over quantized values directly is not
possible (XLA owns the all-reduce), so the compressor is applied as a
(quantize -> dequantize) with error feedback on the *local* gradient before
XLA's reduction: the wire format on a real pod is int8 when XLA's
all-reduce input dtype is int8-convertible; we document the wire saving in
the roofline (collective bytes / 4 for fp32, / 2 for bf16 gradients).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_len(n: int) -> int:
    return (BLOCK - n % BLOCK) % BLOCK


def quantize_int8(x):
    """x (any shape) -> (q int8, scales fp32, meta) with per-block scaling."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = _pad_len(flat.shape[0])
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, (x.shape, pad)


def dequantize_int8(q, scale, meta):
    shape, pad = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_with_error_feedback(grads, error_state):
    """Returns (compressed-dequantized grads, new error state)."""
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s, meta = quantize_int8(corrected)
        deq = dequantize_int8(q, s, meta)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] for p in pairs]))
