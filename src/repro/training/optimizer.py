"""Pure-JAX optimizers: AdamW (small models) and Adafactor (large models —
factored second moments keep optimizer HBM negligible, which is what lets the
235B/400B MoE cells fit a 256-chip v5e pod; see DESIGN.md §5).

Optimizer state is spec-first like parameters: ``opt_spec`` mirrors a
ParamSpec tree so the dry-run lowers the exact state the runnable code uses.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec, is_spec, tree_map_specs


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    spec: Callable          # param_spec_tree -> opt_state_spec_tree
    init: Callable          # params -> opt_state
    update: Callable        # (grads, opt_state, params, step) -> (params, opt_state)


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ------------------------------------------------------------------ AdamW

def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def spec(pspec_tree):
        def one(s: ParamSpec):
            f32 = ParamSpec(s.shape, s.axes, jnp.float32, init="zeros")
            return {"m": f32, "v": f32}
        return tree_map_specs(one, pspec_tree)

    def init(params):
        return jax.tree.map(
            lambda p: {"m": jnp.zeros(p.shape, jnp.float32),
                       "v": jnp.zeros(p.shape, jnp.float32)}, params)

    def update(grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def one(g, s, p):
            g32 = g.astype(jnp.float32)
            m = b1 * s["m"] + (1 - b1) * g32
            v = b2 * s["v"] + (1 - b2) * jnp.square(g32)
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return newp, {"m": m, "v": v}

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        out = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, new_s

    return Optimizer("adamw", spec, init, update)


# ---------------------------------------------------------------- Adafactor

def adafactor(lr: float = 1e-2, decay_pow: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    def _factored(shape):
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def spec(pspec_tree):
        def one(s: ParamSpec):
            if _factored(s.shape):
                return {
                    "vr": ParamSpec(s.shape[:-1], s.axes[:-1], jnp.float32,
                                    init="zeros"),
                    "vc": ParamSpec(s.shape[:-2] + s.shape[-1:],
                                    s.axes[:-2] + s.axes[-1:], jnp.float32,
                                    init="zeros"),
                }
            return {"v": ParamSpec(s.shape, s.axes, jnp.float32,
                                   init="zeros")}
        return tree_map_specs(one, pspec_tree)

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay_pow)

        def one(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                    + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = g32 * rfac[..., None] * cfac[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return newp, new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        out = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    return Optimizer("adafactor", spec, init, update)


def get_optimizer(name: str, lr: float = 1e-3) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr)
    if name == "adafactor":
        return adafactor(lr=lr)
    raise ValueError(name)
