"""Loop-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE, not
multiplied by trip count (verified empirically: a 10-iteration scanned
matmul reports 1/10th the FLOPs of its unrolled twin). Every layer stack in
this codebase is a scan, so the built-in numbers undercount by 23..94x.

This parser walks `compiled.as_text()`:
  * builds a per-computation symbol table (name -> shape),
  * counts dot FLOPs (2 x result elems x contraction size) and collective
    operand/wire bytes per computation,
  * estimates HBM traffic at the thunk level: for instructions in non-fusion
    computations, operand bytes (reads) + result bytes (writes) — fusion
    internals never touch HBM,
  * resolves while-loop trip counts from the condition computation's
    comparison constant and multiplies through the call graph
    (body=/condition=/to_apply=/calls=/fusion).

Per-device numbers (the module is the SPMD per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(.*?)\s+"
                      r"([\w-]+)\((.*)$")
COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s*\(.*\)\s*->.*{")

NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "while", "conditional", "call", "custom-call",
              "after-all", "partition-id", "replica-id", "iota",
              "broadcast", "reshape"}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(x) for x in dims.split(",") if x]))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str                     # operands + attributes


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_operand: float = 0.0
    coll_wire: float = 0.0
    coll_count: int = 0
    calls: list = dataclasses.field(default_factory=list)  # (name, kind)
    is_fusion: bool = False


GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
CALL_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w.-]+)")
BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
OPERAND_RE = re.compile(r"%([\w.-]+)")
CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    entry = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):
            m = COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if cur is None:
            continue
        m = INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def _fusion_info(instrs: List[Instr]) -> dict:
    """Inspect a fusion computation for in-place / artifact patterns."""
    table = {i.name: i.type_str for i in instrs}
    dus_update = 0
    has_ds = False
    real_ops = 0
    for i in instrs:
        if i.op == "dynamic-update-slice":
            ops = OPERAND_RE.findall(i.rest.split(")", 1)[0])
            if len(ops) > 1 and ops[1] in table:
                dus_update += _bytes_of(table[ops[1]])
            else:
                dus_update += _bytes_of(i.type_str)
        elif i.op == "dynamic-slice":
            has_ds = True
        if i.op not in ("parameter", "convert", "bitcast", "tuple",
                        "get-tuple-element", "copy"):
            real_ops += 1
    pure_convert = real_ops == 0
    return {"dus_update_bytes": dus_update, "has_ds": has_ds,
            "pure_convert": pure_convert}


def _analyze_comp(instrs: List[Instr], name: str,
                  fusion_info: Optional[dict] = None) -> CompStats:
    st = CompStats(is_fusion="fused" in name or "fusion" in name)
    fusion_info = fusion_info or {}
    # symbol table: instruction name -> its result type string
    table = {i.name: i.type_str for i in instrs}

    for i in instrs:
        # call edges (explicit attribute labels)
        for attr in ("to_apply", "calls"):
            for cm in re.finditer(attr + r"=%?([\w.-]+)", i.rest):
                st.calls.append((cm.group(1), "call", i.name))
        bm = re.search(r"body=%?([\w.-]+)", i.rest)
        cm_ = re.search(r"condition=%?([\w.-]+)", i.rest)
        if bm:
            st.calls.append((bm.group(1), "while_body", i.name))
        if cm_:
            st.calls.append((cm_.group(1), "while_cond", i.name))
        brm = BRANCH_RE.search(i.rest)
        if brm:
            for b in brm.group(1).split(","):
                st.calls.append((b.strip().lstrip("%"), "branch", i.name))

        # flops: dot ops (conv not used in the dry-run cells)
        if i.op == "dot":
            out_elems = 0
            for dt, dims in _shape_list(i.type_str):
                n = 1
                for d in dims:
                    n *= d
                out_elems += n
            k = 1
            ctr = CONTRACT_RE.search(i.rest)
            ops = OPERAND_RE.findall(i.rest.split(")", 1)[0])
            if ctr and ops:
                lhs_t = table.get(ops[0])
                if lhs_t:
                    shp = _shape_list(lhs_t)
                    if shp:
                        dims = shp[0][1]
                        for ci in ctr.group(1).split(","):
                            if ci:
                                idx = int(ci)
                                if idx < len(dims):
                                    k *= dims[idx]
            st.flops += 2.0 * out_elems * k

        # collectives
        for c in COLLECTIVES:
            if i.op == c or i.op == c + "-start":
                result = _bytes_of(i.type_str)
                g = GROUPS_RE.search(i.rest)
                group = int(g.group(2)) if g else 1
                if c == "all-gather":
                    operand = result // max(group, 1)
                    wire = result - operand
                elif c == "reduce-scatter":
                    operand = result * max(group, 1)
                    wire = operand - result
                elif c == "all-reduce":
                    operand = result
                    wire = 2 * result * (group - 1) // max(group, 1)
                elif c == "all-to-all":
                    operand = result
                    wire = result * (group - 1) // max(group, 1)
                else:
                    operand = result
                    wire = result
                st.coll_operand += operand
                st.coll_wire += wire
                st.coll_count += 1
                break

        # thunk-level HBM traffic (skip containers / fusion internals later)
        if i.op not in NO_TRAFFIC or i.op == "custom-call":
            ops = OPERAND_RE.findall(i.rest.split(")", 1)[0])
            result_b = _bytes_of(i.type_str)
            op_bytes = [_bytes_of(table[o]) for o in ops if o in table]
            if i.op == "dynamic-slice":
                # reads only the slice (+ writes it)
                st.hbm_bytes += 2 * result_b
            elif i.op == "dynamic-update-slice":
                # touches only the updated region (read update, write region)
                upd = (_bytes_of(table[ops[1]])
                       if len(ops) > 1 and ops[1] in table else result_b)
                st.hbm_bytes += 2 * upd
            elif i.op == "gather":
                st.hbm_bytes += 2 * result_b
            elif i.op in ("scatter", "select-and-scatter"):
                upd = (_bytes_of(table[ops[-1]])
                       if ops and ops[-1] in table else result_b)
                st.hbm_bytes += 3 * upd  # read region + update, write back
            elif i.op == "fusion":
                cm = re.search(r"calls=%?([\w.-]+)", i.rest)
                info = fusion_info.get(cm.group(1)) if cm else None
                if info and info["pure_convert"]:
                    # bf16->f32 weight twins: XLA:CPU float-normalization
                    # artifact, absent on TPU (see dryrun.py) — no traffic
                    pass
                elif info and info["dus_update_bytes"]:
                    # in-place DUS fusion: skip the aliased big buffer
                    others = sorted(op_bytes)[:-1] if op_bytes else []
                    st.hbm_bytes += (2 * info["dus_update_bytes"]
                                     + sum(others))
                elif info and info["has_ds"]:
                    # slice-reading fusion: reads slice-sized data only
                    others = sorted(op_bytes)[:-1] if op_bytes else []
                    st.hbm_bytes += 2 * result_b + sum(others)
                else:
                    st.hbm_bytes += sum(op_bytes) + result_b
            else:
                st.hbm_bytes += sum(op_bytes) + result_b
    return st


def _trip_count(instrs: List[Instr]) -> int:
    # condition computations compare the induction var to the trip count,
    # which appears as `%c = s32[] constant(N)`
    consts = []
    for i in instrs:
        if i.op == "constant" and i.type_str.strip().startswith("s32"):
            m = re.match(r"\s*(\d+)", i.rest)
            if m:
                consts.append(int(m.group(1)))
    return max([c for c in consts if 0 < c <= 1_000_000], default=1)


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry_name = comps.pop("__entry_name__")
    comps.pop("__entry__", None)
    finfo = {n: _fusion_info(ins) for n, ins in comps.items()}
    stats = {n: _analyze_comp(ins, n, finfo) for n, ins in comps.items()}

    totals = {"flops": 0.0, "hbm_bytes": 0.0, "coll_operand": 0.0,
              "coll_wire": 0.0, "coll_count": 0.0}
    visited_guard = set()

    def visit(name: str, mult: float, depth=0):
        if name not in stats or depth > 50:
            return
        key = (name, mult)
        st = stats[name]
        if not st.is_fusion:
            totals["hbm_bytes"] += st.hbm_bytes * mult
        totals["flops"] += st.flops * mult
        totals["coll_operand"] += st.coll_operand * mult
        totals["coll_wire"] += st.coll_wire * mult
        totals["coll_count"] += st.coll_count * mult
        # group while calls by instruction to pair body+cond
        whiles = {}
        for (target, kind, instr) in st.calls:
            if kind in ("while_body", "while_cond"):
                whiles.setdefault(instr, {})[kind] = target
            elif kind in ("call", "branch"):
                visit(target, mult, depth + 1)
        for instr, pair in whiles.items():
            cond = pair.get("while_cond")
            body = pair.get("while_body")
            trips = 1
            if cond and cond in comps:
                trips = _trip_count(comps[cond])
            if body:
                visit(body, mult * trips, depth + 1)

    if entry_name:
        visit(entry_name, 1.0)
    return dict(totals)
