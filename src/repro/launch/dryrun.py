import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory / cost / collective analysis.

This is the proof (without hardware) that the distribution config is
coherent: sharding mismatches, compile-time OOM, or unsupported collectives
all fail here. Run one cell per process (compilation state is large):

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b \
        --shape decode_32k --mesh single --out experiments/dryrun

or everything serially with --all (slow; the driver script
`experiments/run_dryrun.sh` fans out subprocesses).
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.configs.base import shape_applicable
from repro.distributed.sharding import shardings_for
from repro.distributed.steps import build_sharded_step
from repro.launch.mesh import make_production_mesh
from repro.models import params as pspec
from repro.models.registry import get_bundle

COLLECTIVE_RE = re.compile(
    r"=\s+(?:\(.*?\)|[a-z0-9]+\[([0-9,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^(]*\(", )
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str):
    """Per-device collective ops with operand-byte estimates.

    Result-shape bytes come from the HLO line; operand bytes are derived per
    op kind (all-gather result = operand x group; reduce-scatter inverse)."""
    ops = []
    for line in hlo_text.splitlines():
        m = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start|-done)?\(", line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        if m.group(2) == "-done":
            continue  # counted at -start
        lhs = line.split("=", 1)[0] + "= " + line.split("=", 1)[1]
        shapes = SHAPE_RE.findall(line.split(m.group(0))[0])
        if not shapes:
            continue
        result_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = GROUPS_RE.search(line)
        group = int(g.group(2)) if g else 1
        if kind == "all-gather":
            operand = result_bytes // max(group, 1)
            wire = result_bytes - operand            # (g-1)/g of result
        elif kind == "reduce-scatter":
            operand = result_bytes * max(group, 1)
            wire = operand - result_bytes
        elif kind == "all-reduce":
            operand = result_bytes
            wire = 2 * result_bytes * (group - 1) // max(group, 1)
        elif kind == "all-to-all":
            operand = result_bytes
            wire = result_bytes * (group - 1) // max(group, 1)
        else:  # collective-permute
            operand = result_bytes
            wire = result_bytes
        ops.append({"kind": kind, "result_bytes": result_bytes,
                    "operand_bytes": operand, "wire_bytes": wire,
                    "group": group})
    return ops


def run_cell(arch: str, shape_name: str, mesh_kind: str, scan_hlo: bool = True,
             chunk: int = 1024):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention "
                          "(pure full-attention arch; DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    step = build_sharded_step(cfg, mesh, shape, chunk=chunk)
    lowered = step.jitted.lower(*step.abstract)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    # loop-aware totals: cost_analysis counts while bodies ONCE; every layer
    # stack here is a scan, so flops/bytes/collectives must be multiplied
    # through the loop nest (repro.launch.hloparse, verified vs unrolled).
    from repro.launch import hloparse
    looped = hloparse.analyze(hlo)

    # XLA:CPU cannot matmul bf16 natively; FloatNormalization hoists an fp32
    # twin of every bf16 matmul weight out of the layer loop (verified via
    # buffer-assignment dumps — EXPERIMENTS.md §Dry-run). On TPU the MXU is
    # bf16-native and these twins do not exist, so we report a TPU-adjusted
    # peak alongside the raw CPU-backend number.
    bundle = get_bundle(cfg)
    p_spec = bundle.spec()
    p_shard = shardings_for(p_spec, mesh, step.rules)
    upcast = 0
    import jax.numpy as jnp
    for ps, sh in zip(pspec._spec_leaves(p_spec),
                      pspec._spec_leaves(p_shard)):
        if ps.dtype == jnp.bfloat16 and len(ps.shape) >= 2:
            local = sh.shard_shape(tuple(ps.shape))
            upcast += int(np.prod(local)) * 4
    if shape.kind == "train":
        # fp32 twin of the remat carry stack (verified in gemma2 dump):
        # n_groups x (B/dp/microbatch) x S x d per stack (enc+dec if encdec)
        dp = 1
        for a in step.rules.get("batch", ()):
            dp *= mesh.shape.get(a, 1)
        n_mb = max(1, min(cfg.microbatches, shape.global_batch // max(dp, 1)))
        b_mb = max(1, shape.global_batch // max(dp, 1) // n_mb)
        groups = cfg.num_layers // max(len(cfg.pattern), 1)
        if cfg.is_encdec:
            groups += cfg.enc_layers
        seq_div = (mesh.shape.get("model", 1)
                   if cfg.seq_shard_train else 1)
        upcast += (groups * b_mb * shape.seq_len * cfg.d_model * 4
                   // seq_div)

    by_kind = {}
    for op in colls:
        k = op["kind"]
        e = by_kind.setdefault(k, {"count": 0, "operand_bytes": 0,
                                   "wire_bytes": 0})
        e["count"] += 1
        e["operand_bytes"] += op["operand_bytes"]
        e["wire_bytes"] += op["wire_bytes"]

    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok",
        "mode": step.rules.get("_mode"),
        "devices": int(len(mesh.devices.flatten())),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
            "cpu_bf16_upcast_artifact": upcast,
            "peak_tpu_estimate": (mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes - upcast),
        },
        "cost": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
        },
        "looped": {   # loop-nest-corrected per-device totals (hloparse)
            "flops": looped["flops"],
            "hbm_bytes": looped["hbm_bytes"],
            "coll_operand_bytes": looped["coll_operand"],
            "coll_wire_bytes": looped["coll_wire"],
            "coll_count": looped["coll_count"],
        },
        "collectives": by_kind,
        "collective_operand_bytes": sum(o["operand_bytes"] for o in colls),
        "collective_wire_bytes": sum(o["wire_bytes"] for o in colls),
        "hlo_bytes": len(hlo),
    }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_NAMES))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--chunk", type=int, default=1024)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                for m in ("single", "multi"):
                    cells.append((a, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, args.mesh)]

    os.makedirs(args.out, exist_ok=True)
    ok = True
    for arch, shape, meshk in cells:
        tag = f"{arch}__{shape}__{meshk}"
        try:
            res = run_cell(arch, shape, meshk, chunk=args.chunk)
        except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
            res = {"arch": arch, "shape": shape, "mesh": meshk,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            ok = False
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1)
        status = res["status"]
        extra = ""
        if status == "ok":
            extra = (f" peak/dev={res['memory']['peak_per_device']/2**30:.2f}GiB"
                     f" flops={res['cost']['flops']:.3e}"
                     f" coll={res['collective_wire_bytes']/2**20:.1f}MiB"
                     f" compile={res['compile_s']}s")
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
