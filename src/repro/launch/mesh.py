"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; nothing else in the codebase does.
"""
from __future__ import annotations

import jax

try:  # AxisType landed in jax 0.5; older releases imply Auto axes
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:
    _AXIS_KW = lambda n: {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_AXIS_KW(len(axes)))
