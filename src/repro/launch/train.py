"""Training launcher: sharded train loop with checkpoint/restart, resumable
data pipeline, and failure-tolerant step execution.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 50 --batch 8 --seq 256 --smoke

`--smoke` uses the reduced config (CPU-runnable); on a pod the full config +
production mesh apply unchanged (the dry-run proves they compile).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed.steps import build_sharded_step
from repro.launch.mesh import make_mesh
from repro.models import params as pspec
from repro.models.registry import get_bundle
from repro.training.optimizer import get_optimizer


def train(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 256,
          smoke: bool = True, ckpt_dir: str = None, ckpt_every: int = 25,
          mesh_shape=None, log_every: int = 10, microbatches=None,
          seed: int = 0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if microbatches is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, microbatches=microbatches)
    shape = ShapeSpec("custom_train", "train", seq, batch)
    n_dev = len(jax.devices())
    mesh = make_mesh(mesh_shape or (n_dev, 1), ("data", "model"))
    step_obj = build_sharded_step(cfg, mesh, shape, chunk=min(1024, seq))

    bundle = get_bundle(cfg)
    spec = bundle.spec()
    opt = get_optimizer(cfg.optimizer)

    start = 0
    if ckpt_dir and (ls := latest_step(ckpt_dir)) is not None:
        start = ls
        abs_p = pspec.abstract(spec)
        abs_o = pspec.abstract(opt.spec(spec))
        params = restore_checkpoint(ckpt_dir, ls, abs_p)
        opt_state = restore_checkpoint(ckpt_dir + "/opt", ls, abs_o)
        print(f"[train] restored step {ls} from {ckpt_dir}")
    else:
        params = pspec.materialize(spec, jax.random.PRNGKey(seed))
        opt_state = opt.init(params)

    source = SyntheticLM(cfg, shape, seed=seed)
    prefetch = Prefetcher(source, start_step=start)
    losses = []
    t0 = time.time()
    try:
        for i in range(start, steps):
            step_id, host_batch = next(prefetch)
            assert step_id == i
            batch_dev = {k: jax.numpy.asarray(v) for k, v in
                         host_batch.items()}
            params, opt_state, metrics = step_obj.jitted(
                params, opt_state, batch_dev,
                jax.numpy.asarray(i, jax.numpy.int32))
            loss = float(metrics["loss"])
            losses.append(loss)
            if i % log_every == 0 or i == steps - 1:
                print(f"[train] step {i:5d} loss {loss:.4f} "
                      f"({(time.time()-t0):.1f}s)", flush=True)
            if ckpt_dir and (i + 1) % ckpt_every == 0:
                save_checkpoint(ckpt_dir, i + 1, params, wait=False)
                save_checkpoint(ckpt_dir + "/opt", i + 1, opt_state,
                                wait=True)
    finally:
        prefetch.close()
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_NAMES))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)
    losses = train(args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, smoke=args.smoke, ckpt_dir=args.ckpt)
    print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
