"""Small shared helpers."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def tree_bytes(tree: Any) -> int:
    """Total bytes of a pytree of arrays or ShapeDtypeStructs."""
    leaves = jax.tree.leaves(tree)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize for l in leaves)


def tree_params(tree: Any) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves)


def human_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}EB"


def human_flops(n: float) -> str:
    for unit in ("F", "KF", "MF", "GF", "TF", "PF"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}EF"


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for the target chip (TPU v5e, per system spec)."""

    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12   # FLOP/s per chip
    hbm_bandwidth: float = 819e9      # bytes/s per chip
    ici_link_bandwidth: float = 50e9  # bytes/s per link
    hbm_capacity: float = 16e9        # bytes per chip
    host_to_hbm_bandwidth: float = 25e9  # bytes/s (PCIe-class DMA, LOAD path)


V5E = HardwareSpec()


def percentile(xs, q: float) -> float:
    if not len(xs):
        return float("nan")
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


def welford_summary(xs) -> dict:
    a = np.asarray(xs, dtype=np.float64)
    if a.size == 0:
        return {"n": 0}
    return {
        "n": int(a.size),
        "mean": float(a.mean()),
        "p50": float(np.percentile(a, 50)),
        "p99": float(np.percentile(a, 99)),
        "p99.9": float(np.percentile(a, 99.9)),
        "max": float(a.max()),
        "min": float(a.min()),
    }
