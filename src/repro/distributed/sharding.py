"""Logical-axis sharding rules.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"seq_kv", "experts", ...). A :class:`Rules` mapping — built per
(config, step-kind, shape, mesh) — resolves each logical axis to zero or more
mesh axes. Two attention TP modes fall out of the same model code:

* ``heads`` mode  (n_heads divisible by the model axis): Megatron-style —
  QKV/O sharded over heads, attention compute local per shard.
* ``context`` mode (n_heads not divisible): QKV/O weights sharded over the
  contracting d_model dim, attention *scores* sharded over the KV-sequence
  dim; softmax reductions over that dim become SPMD all-reduces
  (flash-decode-style partial-softmax combine, expressed at the einsum level).

All constraints are best-effort: a mesh axis that does not evenly divide the
corresponding dim is dropped (important for smoke tests on 1 device and for
leftover/irregular dims).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import params as pspec

Rules = dict


def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def heads_divisible(cfg: ModelConfig, mesh: Mesh) -> bool:
    m = mesh.shape.get("model", 1)
    return cfg.n_heads % m == 0


def kv_heads_divisible(cfg: ModelConfig, mesh: Mesh) -> bool:
    m = mesh.shape.get("model", 1)
    return cfg.n_kv_heads % m == 0


def attn_mode(cfg: ModelConfig, mesh: Mesh, step_kind: str) -> str:
    """heads | context — chosen per (arch, step kind); see DESIGN.md §4."""
    if cfg.pattern and all(k == "ssm" for k in cfg.pattern):
        return "heads"  # irrelevant; ssm uses its own axes
    if step_kind == "decode":
        # The KV cache is the dominant tensor: shard it over kv-heads when
        # possible, otherwise over the sequence dim (context mode).
        return "heads" if kv_heads_divisible(cfg, mesh) else "context"
    return "heads" if heads_divisible(cfg, mesh) else "context"


def make_rules(mesh: Mesh, cfg: ModelConfig, step_kind: str,
               shape: Optional[ShapeSpec] = None) -> Rules:
    dp = _dp_axes(mesh)
    model = ("model",) if "model" in mesh.axis_names else ()
    mode = attn_mode(cfg, mesh, step_kind)
    batch = shape.global_batch if shape is not None else None

    if step_kind == "decode" and batch == 1:
        # Nothing to data-parallelize: give the whole mesh to the sequence /
        # state dims (long-context decode).
        batch_axes = ()
        seq_kv = dp + model if mode == "context" else ()
    else:
        batch_axes = dp
        seq_kv = model if mode == "context" else ()

    rules = {
        "batch": batch_axes,
        "seq": (),
        "seq_act": (model if (step_kind == "train" and cfg.seq_shard_train)
                    else ()),
        "seq_kv": seq_kv,
        "kv_seg": seq_kv,   # segment dim of combine-once context flash
        "heads": model if mode == "heads" else (),
        "heads_o": model if heads_divisible(cfg, mesh) else (),
        "d_model_out": (model if (mode == "context"
                                  and not heads_divisible(cfg, mesh))
                        else ()),
        "kv_heads": model if (mode == "heads" and kv_heads_divisible(cfg, mesh)) else (),
        "head_dim": (),
        "d_model": (),
        "d_model_tp": model if mode == "context" else (),
        "d_ff": model,
        "vocab": model,
        "experts": tuple(a for a in ("pod", "data")
                         if a in mesh.axis_names),
        "expert_ff": model,
        "ssm_heads": (),
        "ssm_hd": model,
        "ssm_state": (),
        "d_rnn": model,
        "conv_w": (),
        "layers": (),
        "frames": (),
        "patches": (),
    }
    rules["_mode"] = mode
    return rules


def spec_for(rules: Rules, axes, shape=None) -> P:
    """PartitionSpec from logical axes, dropping non-dividing/duplicate axes."""
    mesh = _CTX.mesh
    used = set()
    out = []
    for i, ax in enumerate(axes):
        mesh_axes = rules.get(ax, ()) if ax is not None else ()
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        picked = []
        total = 1
        for m in mesh_axes:
            if m in used or mesh is None or m not in mesh.shape:
                continue
            total *= mesh.shape[m]
            picked.append(m)
        if shape is not None and picked:
            if total == 0 or shape[i] % total != 0:
                # Best effort: retry with a prefix of the axes.
                picked2, total2 = [], 1
                for m in picked:
                    if shape[i] % (total2 * mesh.shape[m]) == 0:
                        picked2.append(m)
                        total2 *= mesh.shape[m]
                picked = picked2
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    return P(*out)


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[Rules]):
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def current_mesh_rules():
    return _CTX.mesh, _CTX.rules


def constrain(x, *axes):
    """with_sharding_constraint via logical axes; no-op outside use_rules."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = spec_for(_CTX.rules, axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


def shardings_for(tree, mesh: Mesh, rules: Rules):
    """NamedShardings for a ParamSpec tree (or tree of (shape, axes))."""
    def one(s):
        with use_rules(mesh, rules):
            spec = spec_for(rules, s.axes, tuple(s.shape))
        return NamedSharding(mesh, spec)
    return pspec.tree_map_specs(one, tree)


def shardings_from_axes(abstract_tree, axes_tree, mesh: Mesh, rules: Rules):
    """NamedShardings for a tree of ShapeDtypeStructs + parallel axes tree.

    ``axes_tree`` carries a tuple of logical axis names at every position
    where ``abstract_tree`` carries an array."""
    flat, treedef = jax.tree.flatten(abstract_tree)
    axes_flat = treedef.flatten_up_to(axes_tree)
    out = []
    with use_rules(mesh, rules):
        for sds, axes in zip(flat, axes_flat):
            out.append(NamedSharding(mesh,
                                     spec_for(rules, axes, tuple(sds.shape))))
    return jax.tree.unflatten(treedef, out)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
