"""Step builders: train / prefill / decode, plain and mesh-sharded.

`build_sharded_step` is the single entrypoint used by the dry-run, the
trainer, and the serving engine — so what gets lowered in the multi-pod
dry-run is byte-for-byte what the runnable system executes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.configs.shapes import (batch_logical_axes, decode_cache_len,
                                  inputs_for)
from repro.distributed.sharding import (make_rules, replicated,
                                        shardings_for, shardings_from_axes,
                                        use_rules)
from repro.models import params as pspec
from repro.models.lm import greedy_sample
from repro.models.registry import get_bundle
from repro.training.optimizer import clip_by_global_norm, get_optimizer


def cross_entropy(cfg: ModelConfig, logits, targets):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    return -ll.mean()


# ------------------------------------------------------------ plain steps

def make_train_step(cfg: ModelConfig, opt, chunk: int = 1024,
                    microbatches: Optional[int] = None):
    """Train step with optional gradient accumulation.

    Microbatching bounds the live activation checkpoints (layer inputs saved
    per scan group) to one microbatch — the lever that fits the 94-layer /
    48-layer MoE train cells in 16 GB/chip (EXPERIMENTS.md §Perf)."""
    bundle = get_bundle(cfg)

    def loss_fn(p, mb):
        logits = bundle.train_logits(p, mb, chunk=chunk)
        return cross_entropy(cfg, logits, mb["targets"])

    def finish(params, opt_state, loss, grads, step):
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": step + 1}
        return new_params, new_opt, metrics

    def train_step(params, opt_state, batch, step):
        n = microbatches if microbatches is not None else cfg.microbatches
        b0 = jax.tree.leaves(batch)[0].shape[0]
        if n <= 1 or b0 % n != 0:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return finish(params, opt_state, loss, grads, step)

        micro = jax.tree.map(
            lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch)
        # accumulate in the parameter dtype: an fp32 accumulator would double
        # the parameter footprint per device, which alone overflows 16 GB for
        # the 784B-param llama4 train cell (EXPERIMENTS.md §Perf). bf16
        # accumulation over <=16 microbatches costs <1% gradient noise.
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)

        def body(carry, mb):
            lsum, gacc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            gacc = jax.tree.map(lambda a, x: (a + x.astype(a.dtype)).astype(
                a.dtype), gacc, g)
            return (lsum + l, gacc), None

        (lsum, gsum), _ = jax.lax.scan(body, (jnp.float32(0.0), g0), micro)
        loss = lsum / n
        grads = jax.tree.map(lambda g, p: (g.astype(jnp.float32) / n
                                           ).astype(p.dtype), gsum, params)
        return finish(params, opt_state, loss, grads, step)

    return train_step


def make_prefill_step(cfg: ModelConfig, chunk: int = 1024,
                      cache_len: Optional[int] = None):
    bundle = get_bundle(cfg)

    def prefill_step(params, batch):
        logits, cache = bundle.prefill(params, batch, chunk=chunk,
                                       cache_len=cache_len)
        return greedy_sample(logits), cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    bundle = get_bundle(cfg)

    def decode_step(params, cache, tokens, cur_index):
        logits, new_cache = bundle.decode(params, cache, tokens, cur_index)
        return greedy_sample(logits), new_cache

    return decode_step


# --------------------------------------------------------- sharded builder

@dataclasses.dataclass
class ShardedStep:
    kind: str
    jitted: Any            # jit-wrapped fn, ready for .lower(*abstract)
    abstract: tuple        # abstract args matching the jit signature
    rules: dict
    mesh: Mesh


def _sds_i32():
    return jax.ShapeDtypeStruct((), jnp.int32)


def build_sharded_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec,
                       lr: float = 1e-3, chunk: int = 1024) -> ShardedStep:
    rules = make_rules(mesh, cfg, shape.kind, shape)
    bundle = get_bundle(cfg)
    spec = bundle.spec()
    param_abs = pspec.abstract(spec)
    param_sh = shardings_for(spec, mesh, rules)

    batch_abs = inputs_for(cfg, shape)
    batch_sh = shardings_from_axes(batch_abs, batch_logical_axes(batch_abs),
                                   mesh, rules)

    if shape.kind == "train":
        opt = get_optimizer(cfg.optimizer, lr=lr)
        opt_spec = opt.spec(spec)
        opt_abs = pspec.abstract(opt_spec)
        opt_sh = shardings_for(opt_spec, mesh, rules)
        # largest microbatch count <= cfg.microbatches such that each
        # microbatch still shards evenly over the data axes
        import math
        dp = 1
        for a in rules.get("batch", ()):
            dp *= mesh.shape.get(a, 1)
        n_mb = max(1, min(cfg.microbatches, shape.global_batch // max(dp, 1)))
        while n_mb > 1 and (shape.global_batch % n_mb
                            or (shape.global_batch // n_mb) % dp):
            n_mb -= 1
        inner = make_train_step(cfg, opt, chunk=chunk, microbatches=n_mb)

        def fn(params, opt_state, batch, step):
            with use_rules(mesh, rules):
                return inner(params, opt_state, batch, step)

        metrics_sh = {"loss": replicated(mesh), "grad_norm": replicated(mesh),
                      "step": replicated(mesh)}
        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, opt_sh, batch_sh, replicated(mesh)),
            out_shardings=(param_sh, opt_sh, metrics_sh),
            donate_argnums=(0, 1),
        )
        return ShardedStep("train", jitted,
                           (param_abs, opt_abs, batch_abs, _sds_i32()),
                           rules, mesh)

    if shape.kind == "prefill":
        cross_len = shape.seq_len if cfg.is_encdec else 0
        cache_axes = bundle.cache_axes(cross_len)
        inner = make_prefill_step(cfg, chunk=chunk)

        def fn(params, batch):
            with use_rules(mesh, rules):
                return inner(params, batch)

        # The emitted cache is laid out for DECODE consumption (kv-replicated
        # archs get a seq-sharded cache, not a replicated one) — one reshard
        # at the end of prefill instead of a fat replicated output.
        dec_rules = make_rules(mesh, cfg, "decode", shape)
        out_abs = jax.eval_shape(fn, param_abs, batch_abs)
        tok_sh = shardings_from_axes(out_abs[0], ("batch", "seq"),
                                     mesh, rules)
        cache_sh = shardings_from_axes(out_abs[1], cache_axes, mesh,
                                       dec_rules)
        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh),
                         out_shardings=(tok_sh, cache_sh))
        return ShardedStep("prefill", jitted, (param_abs, batch_abs),
                           rules, mesh)

    # decode
    self_len, cross_len = decode_cache_len(cfg, shape)
    cache_abs = bundle.cache_abstract(shape.global_batch, self_len,
                                      cross_len)
    cache_axes = bundle.cache_axes(cross_len)
    cache_sh = shardings_from_axes(cache_abs, cache_axes, mesh, rules)
    inner = make_decode_step(cfg)

    def fn(params, cache, tokens, cur_index):
        with use_rules(mesh, rules):
            return inner(params, cache, tokens, cur_index)

    tok_abs = batch_abs["tokens"]
    tok_sh = shardings_from_axes(tok_abs, ("batch", "seq"), mesh, rules)
    jitted = jax.jit(
        fn,
        in_shardings=(param_sh, cache_sh, tok_sh, replicated(mesh)),
        out_shardings=(tok_sh, cache_sh),
        donate_argnums=(1,),
    )
    return ShardedStep("decode", jitted,
                       (param_abs, cache_abs, tok_abs, _sds_i32()),
                       rules, mesh)
