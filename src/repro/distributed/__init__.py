from repro.distributed.sharding import (  # noqa: F401
    Rules,
    make_rules,
    spec_for,
    constrain,
    use_rules,
    shardings_for,
    current_mesh_rules,
)
