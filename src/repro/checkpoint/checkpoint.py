"""Sharded, atomic, resumable checkpoints.

Layout: <dir>/step_<N>/ holding one .npy per leaf (flattened key path) plus
a manifest; writes go to a temp dir first and are atomically renamed, so a
crash mid-save never corrupts the latest checkpoint (restart-safety).

On restore, arrays are placed via `jax.device_put` with the *target* sharding
— which may differ from the sharding at save time, giving free resharding
across topology changes (elastic restarts: save on 256 chips, resume on 512).

On a real multi-host pod each host writes only the shards it owns
(`addressable_shards`); on this single-process container that is the whole
array. The manifest records the global shape so restore is host-count
agnostic.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree.flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = ".".join(re.sub(r"[^A-Za-z0-9_-]", "_", str(p)) for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    wait: bool = True) -> threading.Thread:
    """Atomic (optionally async) checkpoint write."""
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten(tree)
    host_arrays = {k: np.asarray(v) for k, v in flat.items()}

    def _write():
        tmp = tempfile.mkdtemp(dir=directory, prefix=f".tmp_step_{step}_")
        try:
            manifest = {}
            for k, a in host_arrays.items():
                raw = a.view(np.uint16) if str(a.dtype) == "bfloat16" else a
                np.save(os.path.join(tmp, k + ".npy"), raw)
                manifest[k] = {"shape": list(a.shape), "dtype": str(a.dtype)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "arrays": manifest}, f)
            final = os.path.join(directory, f"step_{step}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)

    t = threading.Thread(target=_write)
    t.start()
    if wait:
        t.join()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of `target` (arrays/ShapeDtypeStructs);
    `shardings` (same structure) re-places shards on the current mesh."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)["arrays"]
    flat_t, treedef = _flatten(target)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = {}
    for k, tgt in flat_t.items():
        a = np.load(os.path.join(path, k + ".npy"))
        if manifest.get(k, {}).get("dtype") == "bfloat16":
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        a = a.astype(tgt.dtype) if hasattr(tgt, "dtype") else a
        if k in flat_s:
            out[k] = jax.device_put(a, flat_s[k])
        else:
            out[k] = jax.numpy.asarray(a)
    leaves, _ = _flatten(target)
    return jax.tree.unflatten(treedef, [out[k] for k in leaves])
