"""llama4-maverick-400b-a17b [moe]: 128 experts top-1 + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per-expert) vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. 40 heads ∤ 16 ->
context-parallel attention; experts over the data axis. Early-fusion
multimodality is out of scope for the backbone cells (text path only).
"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp="swiglu",
    rope_theta=5e5,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  shared_expert=True),
    optimizer="adafactor",
    microbatches=16,
    seq_shard_train=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, n_heads=5, n_kv_heads=1,
        head_dim=16, d_ff=32,
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=32,
                      shared_expert=True),
        vocab_size=503)
