"""gemma2-27b [dense]: local+global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000 [arXiv:2408.00118;
hf]. Pattern = (local-4096, global) x 23; attn softcap 50, final softcap 30,
gemma-style embed scaling + post-norms; tied embeddings. Runs long_500k:
local layers keep a 4096 ring KV, global layers shard the 524288 KV over
(seq x heads).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    pattern=("local", "attn"),
    window=4096,
    mlp="swiglu",
    attn_softcap=50.0,
    final_softcap=30.0,
    scale_embed=True,
    post_norms=True,
    tie_embeddings=True,
    optimizer="adafactor",
    microbatches=8,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=503, window=16)
