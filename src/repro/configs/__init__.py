"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from repro.configs.base import (SHAPES, LONG_CONTEXT_ARCHS, ModelConfig,
                                ShapeSpec, shape_applicable)

from repro.configs import (gemma2_27b, llama4_maverick_400b_a17b,
                           llava_next_mistral_7b, mamba2_130m,
                           phi4_mini_3_8b, qwen2_0_5b, qwen3_moe_235b_a22b,
                           recurrentgemma_2b, seamless_m4t_medium,
                           starcoder2_3b)

_MODULES = {
    "seamless-m4t-medium": seamless_m4t_medium,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "mamba2-130m": mamba2_130m,
    "gemma2-27b": gemma2_27b,
    "starcoder2-3b": starcoder2_3b,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "qwen2-0.5b": qwen2_0_5b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "recurrentgemma-2b": recurrentgemma_2b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _MODULES[name].smoke_config()


def all_cells():
    """All (arch, shape) dry-run cells incl. applicability flag."""
    out = []
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for shape in SHAPES.values():
            out.append((name, shape.name, shape_applicable(cfg, shape)))
    return out
