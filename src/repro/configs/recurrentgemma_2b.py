"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000 [arXiv:2402.19427;
hf]. Pattern (rec, rec, local) x 8 + (rec, rec) leftover = 26 blocks.
Fixed-size LRU state + 2048-window KV -> O(1) decode; runs long_500k.
"""
import dataclasses

from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=("rec", "rec", "local"),
    window=2048,
    mlp="swiglu",
    scale_embed=True,
    rglru=RGLRUConfig(d_rnn=2560, conv_width=4),
    tie_embeddings=True,
    optimizer="adamw",
    microbatches=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=64, n_heads=2, n_kv_heads=1,
        head_dim=32, d_ff=128, vocab_size=503, window=16,
        rglru=RGLRUConfig(d_rnn=64, conv_width=4))
