"""mamba2-130m [ssm]: attention-free SSD (state-space duality).

24L d_model=768 d_ff=0 vocab=50280, ssm_state=128 [arXiv:2405.21060;
unverified]. Blocks are pure Mamba2 mixers (no MLP): d_inner=2*d_model=1536,
24 SSD heads of dim 64, state 128. O(1) decode state — runs long_500k.
"""
import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    n_heads=24,          # == SSD heads (d_model*expand/head_dim)
    n_kv_heads=24,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    mlp="none",
    pattern=("ssm",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    tie_embeddings=True,
    optimizer="adamw",
    microbatches=2,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, n_heads=8, n_kv_heads=8,
        head_dim=16, vocab_size=503,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                      chunk=16))
