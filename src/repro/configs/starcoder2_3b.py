"""starcoder2-3b [dense]: GQA + RoPE, standard GeLU MLP with biases.

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 [arXiv:2402.19173;
hf]. 24 heads do not divide the 16-way model axis -> context-parallel
attention (DESIGN.md §4).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    mlp="gelu",
    qkv_bias=True,
    rope_theta=1e5,
    optimizer="adafactor",
    microbatches=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=503)
