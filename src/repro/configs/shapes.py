"""Input specs per (architecture x shape): ShapeDtypeStruct stand-ins that
are weak-type-correct, shardable, and allocate nothing — the dry-run lowers
exactly these. `batch_logical_axes` mirrors each batch with sharding axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec

# decode-time self-cache length for encoder-decoder models (the encoder/cross
# context carries the shape's seq_len; generated translations are short).
ENCDEC_DEC_LEN = 4096
# decoder prime length for enc-dec prefill
ENCDEC_PRIME = 1024


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_inputs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        return {
            "frames": sds((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": sds((B, S), jnp.int32),
            "targets": sds((B, S), jnp.int32),
        }
    if cfg.modality == "image_patches":
        st = S - cfg.img_tokens
        return {
            "tokens": sds((B, st), jnp.int32),
            "image_embeds": sds((B, cfg.img_tokens, cfg.d_model),
                                jnp.bfloat16),
            "targets": sds((B, st), jnp.int32),
        }
    return {
        "tokens": sds((B, S), jnp.int32),
        "targets": sds((B, S), jnp.int32),
    }


def prefill_inputs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        return {
            "frames": sds((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": sds((B, min(ENCDEC_PRIME, S)), jnp.int32),
        }
    if cfg.modality == "image_patches":
        return {
            "tokens": sds((B, S - cfg.img_tokens), jnp.int32),
            "image_embeds": sds((B, cfg.img_tokens, cfg.d_model),
                                jnp.bfloat16),
        }
    return {"tokens": sds((B, S), jnp.int32)}


def decode_inputs(cfg: ModelConfig, shape: ShapeSpec):
    B = shape.global_batch
    return {
        "tokens": sds((B, 1), jnp.int32),
        "cur_index": sds((), jnp.int32),
    }


def batch_logical_axes(batch):
    """Logical axes for a train/prefill/decode batch dict."""
    axes = {}
    for k, v in batch.items():
        if k == "cur_index":
            axes[k] = ()
        elif getattr(v, "ndim", len(getattr(v, "shape", ()))) == 3 or (
                hasattr(v, "shape") and len(v.shape) == 3):
            axes[k] = ("batch", "seq", "d_model")
        else:
            axes[k] = ("batch", "seq")
    return axes


def decode_cache_len(cfg: ModelConfig, shape: ShapeSpec):
    """(self_len, cross_len) for decode-shape caches."""
    if cfg.is_encdec:
        return min(ENCDEC_DEC_LEN, shape.seq_len), shape.seq_len
    return shape.seq_len, 0


def inputs_for(cfg: ModelConfig, shape: ShapeSpec):
    return {
        "train": train_inputs,
        "prefill": prefill_inputs,
        "decode": decode_inputs,
    }[shape.kind](cfg, shape)
