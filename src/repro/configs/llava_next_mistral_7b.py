"""llava-next-mistral-7b [vlm]: Mistral-7B backbone + anyres patch stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. The vision tower +
projector is a stub: input_specs provides 2880 precomputed patch embeddings
(anyres 4 tiles + base image, 576 tokens each) at d_model.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    mlp="swiglu",
    rope_theta=1e6,
    modality="image_patches",
    img_tokens=2880,
    optimizer="adafactor",
    microbatches=8,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=503, img_tokens=8)
