"""seamless-m4t-medium [audio]: enc-dec multimodal backbone.

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206
[arXiv:2308.11596; hf]. Interpreted as 12 encoder + 12 decoder layers; the
speech frontend is a stub (input_specs provides precomputed frame embeddings
at d_model). Sinusoidal/relative positions simplified to RoPE (DESIGN.md).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    mlp="gelu",
    is_encdec=True,
    enc_layers=12,
    modality="audio_frames",
    optimizer="adamw",
    microbatches=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=503)
