"""phi4-mini-3.8b [dense]: RoPE + SwiGLU + GQA, 200k vocab.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 [arXiv:2412.08905;
hf]. 24 heads ∤ 16 -> context-parallel attention.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    mlp="swiglu",
    tie_embeddings=True,
    optimizer="adafactor",
    microbatches=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=503)
