"""qwen2-0.5b [dense]: GQA with QKV bias, tied embeddings.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 [arXiv:2407.10671;
hf]. 14 heads ∤ 16 -> context-parallel attention.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    mlp="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    optimizer="adamw",
    microbatches=2,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, n_heads=7, n_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=503)
