"""Model/shape configuration dataclasses.

Every assigned architecture is described by a :class:`ModelConfig`. The same
config object drives parameter-spec construction, forward functions, sharding
rules, the dry-run, and the serving engine, so there is exactly one source of
truth per architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.utils import round_up

# Block kinds that may appear in ``ModelConfig.pattern``.
ATTN = "attn"      # full (global) attention
LOCAL = "local"    # sliding-window attention (window = cfg.window)
SSM = "ssm"        # Mamba2 SSD mixer
REC = "rec"        # RG-LRU recurrent block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False          # llama4-style always-on expert
    capacity_factor: float = 1.25
    min_capacity: int = 4
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256                     # SSD chunk length


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0                       # 0 => d_model
    conv_width: int = 4
    c: float = 8.0                       # RG-LRU gate sharpness constant


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    pattern: tuple = (ATTN,)             # repeating block-kind pattern
    window: int = 0                      # sliding window for LOCAL blocks
    mlp: str = "swiglu"                  # swiglu|gelu|none
    norm_eps: float = 1e-6
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    scale_embed: bool = False            # gemma-style sqrt(d_model) scaling
    post_norms: bool = False             # gemma2-style post-block norms
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # encoder-decoder (seamless)
    is_encdec: bool = False
    enc_layers: int = 0

    # modality stub: None | "image_patches" | "audio_frames"
    modality: Optional[str] = None
    img_tokens: int = 0                  # patch-embedding token count (vlm)

    # distribution
    optimizer: str = "adamw"             # adamw|adafactor
    remat: bool = True
    microbatches: int = 1                # gradient-accumulation splits
    seq_shard_train: bool = False        # Megatron-SP residual activations

    # hints for serving memory planning
    sliding_kv: bool = True              # LOCAL layers keep window-sized KV

    @property
    def vocab_padded(self) -> int:
        # Padded so the vocab dim shards evenly over a 16-way axis and stays
        # lane-aligned (multiples of 256).
        return round_up(self.vocab_size, 256)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def pattern_split(self):
        """(pattern, n_groups, leftover): layers = pattern*n_groups + leftover."""
        p = self.pattern
        n_groups = self.num_layers // len(p)
        leftover = tuple(p[: self.num_layers % len(p)])
        return p, n_groups, leftover


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                            # train|prefill|decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k requires sub-quadratic attention / bounded state; pure
# full-attention archs skip it (documented in DESIGN.md §4).
LONG_CONTEXT_ARCHS = frozenset(
    {"mamba2-130m", "recurrentgemma-2b", "gemma2-27b"}
)


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.name in LONG_CONTEXT_ARCHS
    return True
