"""qwen3-moe-235b-a22b [moe]: 128 experts, top-8.

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per-expert) vocab=151936
[hf:Qwen/Qwen3-30B-A3B; hf]. Expert parallelism over the data axis
(128e / 16 = 8 per shard), expert d_ff TP over the model axis. 64 q-heads
shard over the 16-way model axis; kv=4 replicated for prefill/train, decode
uses context-sharded KV.
"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    mlp="swiglu",
    rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
    optimizer="adafactor",
    microbatches=16,
    seq_shard_train=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=32,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32),
        vocab_size=503)
