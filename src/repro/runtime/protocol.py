"""Versioned wire protocol for the distributed runtime (DESIGN.md §5).

Framing: every message is one frame — a 4-byte big-endian length prefix
followed by a UTF-8 JSON object. JSON keeps the protocol dependency-free
and debuggable (`nc` + eyeballs); floats round-trip exactly through
Python's repr-based encoder, which the loopback decision-equivalence
tests rely on.

Every message carries `{"v": PROTOCOL_VERSION, "kind": <str>, ...}`.
Kinds:

  membership   HELLO (worker -> controller: worker spec + optional seed
               profiles), WELCOME (ack + controller parameters), GOODBYE /
               GOODBYE_ACK (graceful leave, either direction)
  liveness     PING / PONG (controller-initiated heartbeats; PONG echoes
               the send stamp so the controller estimates per-worker RTT)
  clock sync   SYNC / SYNC_ACK (worker-initiated Cristian exchange: the
               worker maps controller-clock action windows into its local
               clock and reports result stamps back on the controller's
               timeline — cross-boundary span stitching)
  serving      ACTION (controller -> worker), RESULT (worker ->
               controller), SUBMIT / RESPONSE (remote request clients)
  telemetry    TELEMETRY (worker -> controller: batched gauge samples,
               flushed periodically and on daemon shutdown)

Codec functions are pure dict<->dataclass mappers over the types in
`repro.core.actions` / `repro.telemetry.events`; ids are preserved, never
regenerated, so the controller's bookkeeping (outstanding actions, open
spans) works unchanged across the boundary.
"""
from __future__ import annotations

import json
import struct
from typing import Iterator, List, Optional

from repro.core.actions import Action, ActionType, Request, Result, \
    ResultStatus
from repro.telemetry.events import GaugeSample

PROTOCOL_VERSION = 1
MAX_FRAME_BYTES = 16 << 20          # sanity bound against corrupt streams
_LEN = struct.Struct(">I")


class ProtocolError(Exception):
    pass


# ----------------------------------------------------------------- framing
def encode_frame(msg: dict) -> bytes:
    # allow_nan=True: best-effort requests carry slo=inf, and Python's JSON
    # Infinity extension round-trips it (both endpoints are this codec)
    body = json.dumps(msg, separators=(",", ":"), allow_nan=True) \
        .encode("utf-8")
    if len(body) > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame too large: {len(body)} bytes")
    return _LEN.pack(len(body)) + body


class FrameDecoder:
    """Incremental frame reassembly: feed() arbitrary byte chunks, get
    complete decoded messages out (TCP gives no message boundaries)."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[dict]:
        self._buf.extend(data)
        out: List[dict] = []
        buf = self._buf
        while True:
            if len(buf) < _LEN.size:
                break
            (n,) = _LEN.unpack_from(buf, 0)
            if n > MAX_FRAME_BYTES:
                raise ProtocolError(f"frame length {n} exceeds bound")
            if len(buf) < _LEN.size + n:
                break
            body = bytes(buf[_LEN.size:_LEN.size + n])
            del buf[:_LEN.size + n]
            try:
                msg = json.loads(body)
            except ValueError as e:
                raise ProtocolError(f"bad frame payload: {e}") from e
            if not isinstance(msg, dict):
                raise ProtocolError("frame payload is not an object")
            out.append(msg)
        return out


def check_version(msg: dict) -> dict:
    v = msg.get("v")
    if v != PROTOCOL_VERSION:
        raise ProtocolError(
            f"protocol version mismatch: got {v!r}, "
            f"want {PROTOCOL_VERSION}")
    return msg


def field(msg: dict, key: str):
    """Required frame field; missing => ProtocolError (frame boundary)."""
    try:
        return msg[key]
    except (KeyError, TypeError):
        raise ProtocolError(f"frame missing field {key!r}") from None


def decode(codec, payload):
    """Run a codec over untrusted payload, converting structural errors
    into ProtocolError — so frame handlers raise exactly one exception
    type for malformed input and the server's frame-boundary guard can
    close the offending channel without also swallowing internal bugs."""
    try:
        return codec(payload)
    except (KeyError, ValueError, TypeError, IndexError,
            AttributeError) as e:
        name = getattr(codec, "__name__", "codec")
        raise ProtocolError(f"malformed payload for {name}: {e}") from e


def _msg(kind: str, **fields) -> dict:
    fields["v"] = PROTOCOL_VERSION
    fields["kind"] = kind
    return fields


# ------------------------------------------------------------------ codecs
def action_to_wire(a: Action) -> dict:
    return {"id": a.id, "type": a.type.value, "model_id": a.model_id,
            "worker_id": a.worker_id, "gpu_id": a.gpu_id,
            "earliest": a.earliest, "latest": a.latest,
            "expected_duration": a.expected_duration,
            "batch_size": a.batch_size,
            "request_ids": list(a.request_ids),
            "issued_at": a.issued_at,
            "expected_completion": a.expected_completion}


def action_from_wire(d: dict) -> Action:
    # type coercions are identity for well-formed frames (float of a
    # float, int of an int) but turn malicious values — a string where
    # arithmetic expects a number — into errors *inside* `decode`, at
    # the frame boundary, instead of deep in the controller/worker
    return Action(type=ActionType(d["type"]), model_id=str(d["model_id"]),
                  worker_id=str(d["worker_id"]), gpu_id=int(d["gpu_id"]),
                  earliest=float(d["earliest"]), latest=float(d["latest"]),
                  expected_duration=float(d["expected_duration"]),
                  batch_size=int(d.get("batch_size", 1)),
                  request_ids=tuple(int(i)
                                    for i in d.get("request_ids", ())),
                  id=int(d["id"]),
                  issued_at=float(d.get("issued_at", 0.0)),
                  expected_completion=float(
                      d.get("expected_completion", 0.0)))


def result_to_wire(r: Result) -> dict:
    return {"action_id": r.action_id, "action_type": r.action_type.value,
            "model_id": r.model_id, "worker_id": r.worker_id,
            "gpu_id": r.gpu_id, "status": r.status.value,
            "t_start": r.t_start, "t_end": r.t_end,
            "duration": r.duration, "batch_size": r.batch_size,
            "request_ids": list(r.request_ids),
            "t_received": r.t_received}


def result_from_wire(d: dict) -> Result:
    return Result(action_id=int(d["action_id"]),
                  action_type=ActionType(d["action_type"]),
                  model_id=str(d["model_id"]), worker_id=str(d["worker_id"]),
                  gpu_id=int(d["gpu_id"]), status=ResultStatus(d["status"]),
                  t_start=float(d["t_start"]), t_end=float(d["t_end"]),
                  duration=float(d["duration"]),
                  batch_size=int(d.get("batch_size", 1)),
                  request_ids=tuple(int(i)
                                    for i in d.get("request_ids", ())),
                  t_received=float(d.get("t_received", 0.0)))


def request_to_wire(r: Request) -> dict:
    return {"id": r.id, "model_id": r.model_id, "arrival": r.arrival,
            "slo": r.slo, "batchable": r.batchable,
            "completion": r.completion, "status": r.status}


def request_from_wire(d: dict) -> Request:
    completion = d.get("completion")
    status = d.get("status")
    return Request(model_id=str(d["model_id"]), arrival=float(d["arrival"]),
                   slo=float(d["slo"]), id=int(d["id"]),
                   batchable=bool(d.get("batchable", True)),
                   completion=None if completion is None
                   else float(completion),
                   status=None if status is None else str(status))


def gauge_to_wire(g: GaugeSample) -> list:
    return [g.name, g.t, g.value]


def gauge_from_wire(x: list) -> GaugeSample:
    return GaugeSample(name=str(x[0]), t=float(x[1]), value=float(x[2]))


# ------------------------------------------------------------ constructors
def hello(worker_id: str, gpus: List[dict],
          profiles: Optional[dict] = None) -> dict:
    """`profiles` maps (action_type, model_id, batch) -> seconds; sent as
    a flat list so JSON keys stay strings."""
    wire_profiles = None
    if profiles:
        wire_profiles = [[t, mid, b, d]
                         for (t, mid, b), d in profiles.items()]
    return _msg("hello", worker_id=worker_id, gpus=gpus,
                profiles=wire_profiles)


def gpus_from_hello(msg: dict) -> List[dict]:
    """Validated pagecache geometry from a HELLO (ints or it's a
    ProtocolError via `decode`)."""
    return [{"total_pages": int(g["total_pages"]),
             "page_bytes": int(g["page_bytes"])} for g in field(msg, "gpus")]


def profiles_from_hello(msg: dict) -> Optional[dict]:
    wire = msg.get("profiles")
    if not wire:
        return None
    return {(str(t), str(mid), int(b)): float(d) for t, mid, b, d in wire}


def welcome(worker_id: str, heartbeat_interval: float) -> dict:
    return _msg("welcome", worker_id=worker_id,
                heartbeat_interval=heartbeat_interval)


def ping(seq: int, t_sent: float) -> dict:
    return _msg("ping", seq=seq, t_sent=t_sent)


def pong(seq: int, t_sent: float, hold: float = 0.0) -> dict:
    """`hold` is the worker's reply turnaround (local receive -> send, in
    seconds): the controller subtracts it from the measured round-trip so
    net-delay estimates cover the network, not the worker's result_delay."""
    return _msg("pong", seq=seq, t_sent=t_sent, hold=hold)


def sync(t0: float) -> dict:
    return _msg("sync", t0=t0)


def sync_ack(t0: float, t_remote: float) -> dict:
    return _msg("sync_ack", t0=t0, t_remote=t_remote)


def action_msg(a: Action) -> dict:
    return _msg("action", action=action_to_wire(a))


def result_msg(r: Result) -> dict:
    return _msg("result", result=result_to_wire(r))


def telemetry_msg(gauges: List[GaugeSample]) -> dict:
    return _msg("telemetry", gauges=[gauge_to_wire(g) for g in gauges])


def submit_msg(r: Request) -> dict:
    return _msg("submit", request=request_to_wire(r))


def response_msg(r: Request, override_id: Optional[int] = None) -> dict:
    """`override_id` restores the client's own request id: controller-side
    ids are re-issued on SUBMIT (per-process id counters collide across
    client processes), but the client correlates by the id it sent."""
    wire = request_to_wire(r)
    if override_id is not None:
        wire["id"] = override_id
    return _msg("response", request=wire)


def goodbye(reason: str = "") -> dict:
    return _msg("goodbye", reason=reason)


def goodbye_ack() -> dict:
    return _msg("goodbye_ack")


def iter_frames(data: bytes) -> Iterator[dict]:
    """Decode a fully-buffered byte string (tests / JSONL-style captures)."""
    dec = FrameDecoder()
    yield from dec.feed(data)
