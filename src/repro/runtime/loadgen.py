"""Load-generator process: `python -m repro.runtime.loadgen`.

The third tier of the paper's topology (workload | controller | workers,
§6): drives the seeded generators from `serving/workload.py` through a
`RemoteClient` against a remote controller over TCP, and reports
*client-observed* goodput and latency percentiles at exit — SLO
attainment measured on the client's side of the network, where the paper
measures it.

One process is one connection (RealClock EventLoop + RealtimePump +
TcpChannel). `--processes N` forks N child loadgens with spread seeds
and aggregates their results — a multi-process open/closed/MAF workload
front end, so the client tier scales independently of the controller.

Output: exactly one JSON object on stdout (machine-readable; the
three-process demo and CI smoke parse it), human progress on stderr.

    python -m repro.runtime.loadgen --controller 127.0.0.1:9000 \
        --workload open --rate 20 --duration 3 --processes 2
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.core.clock import EventLoop, RealClock, RealtimePump
from repro.runtime.client import RemoteClient
from repro.runtime.transport import tcp_connect
from repro.serving.workload import WORKLOAD_KINDS, build_workload
from repro.telemetry.recorder import Recorder
from repro.telemetry.reports import quantile


def model_ids(n_models: int):
    """Names of the shared demo model set (`runtime.worker.demo_models`):
    both sides of the TCP demo must agree on them."""
    return [f"m{i}" for i in range(n_models)]


def _connect_with_retry(host: str, port: int, post, deadline: float):
    t0 = time.monotonic()
    while True:
        try:
            return tcp_connect(host, port, post)
        except OSError:
            if time.monotonic() - t0 >= deadline:
                raise
            time.sleep(0.1)


def _run_single(args) -> dict:
    host, _, port = args.controller.rpartition(":")
    loop = EventLoop(RealClock())
    pump = RealtimePump(loop, max_poll=0.005)
    recorder = Recorder()
    if args.telemetry_jsonl:
        recorder.stream_to(args.telemetry_jsonl,
                           rotate_bytes=args.rotate_bytes)
    channel = _connect_with_retry(host, int(port), pump.post,
                                  args.connect_timeout)
    client = RemoteClient(loop, channel, recorder=recorder)
    start = loop.now()
    gens = build_workload(loop, client.submit, model_ids(args.n_models),
                          kind=args.workload, slo=args.slo, rate=args.rate,
                          concurrency=args.concurrency, start=start,
                          duration=args.duration, seed=args.seed,
                          total_rate=args.total_rate)
    client.attach(gens)
    print(f"[loadgen] driving {args.workload} workload for "
          f"{args.duration}s against {args.controller}",
          file=sys.stderr, flush=True)
    pump.run(timeout=args.duration + 0.05)
    # generators have stopped; wait for the tail of in-flight responses
    pump.run(until=lambda: client.in_flight == 0, timeout=args.drain)
    client.close()
    recorder.close_stream()

    out = client.summary()
    out["report"] = client.report()
    if args.emit_latencies:
        out["latencies"] = client.latencies
    return out


def _child_cmd(args, i: int) -> list:
    """Child loadgen command, rebuilt from parsed args (immune to the
    --flag=value vs --flag value spelling of the parent's argv): single
    process, spread seed, raw latencies for exact percentile merging."""
    cmd = [sys.executable, "-m", "repro.runtime.loadgen",
           "--controller", args.controller, "--workload", args.workload,
           "--n-models", str(args.n_models), "--rate", str(args.rate),
           "--concurrency", str(args.concurrency), "--slo", str(args.slo),
           "--duration", str(args.duration), "--drain", str(args.drain),
           "--connect-timeout", str(args.connect_timeout),
           "--processes", "1", "--seed", str(args.seed + 1000 * i),
           "--emit-latencies"]
    if args.total_rate is not None:
        cmd += ["--total-rate", str(args.total_rate)]
    if args.telemetry_jsonl:
        cmd += ["--telemetry-jsonl", f"{args.telemetry_jsonl}.{i}"]
    if args.rotate_bytes is not None:
        cmd += ["--rotate-bytes", str(args.rotate_bytes)]
    return cmd


def _run_parent(args) -> dict:
    """Fan out N child loadgens (spread seeds), aggregate their JSON."""
    procs = [subprocess.Popen(_child_cmd(args, i), env=dict(os.environ),
                              stdout=subprocess.PIPE, text=True)
             for i in range(args.processes)]
    outs, rcs = [], []
    for pr in procs:
        try:
            stdout, _ = pr.communicate(
                timeout=args.duration + args.drain + 60)
        except subprocess.TimeoutExpired:
            pr.kill()
            stdout, _ = pr.communicate()
        rcs.append(pr.returncode)
        if pr.returncode == 0:
            outs.append(json.loads(stdout))
    lats = sorted(x for o in outs for x in o.get("latencies", ()))
    agg = {k: sum(o[k] for o in outs)
           for k in ("sent", "goodput", "timeout", "rejected",
                     "in_flight", "lost")}
    agg.update(p50=quantile(lats, 0.50), p99=quantile(lats, 0.99),
               child_returncodes=rcs,
               children=[{k: v for k, v in o.items() if k != "latencies"}
                         for o in outs])
    return agg


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.runtime.loadgen",
        description="Clockwork load generator: drives seeded open/closed/"
                    "MAF workloads through a remote SUBMIT/RESPONSE client "
                    "and reports client-observed goodput + latency.")
    p.add_argument("--controller", required=True, metavar="HOST:PORT")
    p.add_argument("--workload", choices=WORKLOAD_KINDS, default="open")
    p.add_argument("--n-models", type=int, default=4,
                   help="size of the shared demo model set (m0..m{n-1})")
    p.add_argument("--rate", type=float, default=20.0,
                   help="per-model open-loop rate (r/s)")
    p.add_argument("--total-rate", type=float, default=None,
                   help="maf: total rate split across models "
                        "(default rate * n_models)")
    p.add_argument("--concurrency", type=int, default=4,
                   help="closed-loop outstanding requests per model")
    p.add_argument("--slo", type=float, default=0.25)
    p.add_argument("--duration", type=float, default=3.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--processes", type=int, default=1,
                   help="fork this many child loadgens (spread seeds) "
                        "and aggregate their results")
    p.add_argument("--drain", type=float, default=2.0,
                   help="extra seconds to wait for in-flight responses")
    p.add_argument("--connect-timeout", type=float, default=10.0)
    p.add_argument("--telemetry-jsonl", default=None,
                   help="stream client-side spans to this JSONL file")
    p.add_argument("--rotate-bytes", type=int, default=None)
    p.add_argument("--emit-latencies", action="store_true",
                   help="include raw latency samples in the JSON output "
                        "(the parent process uses this for exact "
                        "percentile aggregation)")
    args = p.parse_args(argv)

    if args.processes > 1:
        out = _run_parent(args)
        ok = all(rc == 0 for rc in out["child_returncodes"])
    else:
        out = _run_single(args)
        ok = True
    print(f"[loadgen] goodput={out['goodput']}/{out['sent']} "
          f"p50={out['p50'] * 1e3:.1f}ms p99={out['p99'] * 1e3:.1f}ms "
          f"timeout={out['timeout']} rejected={out['rejected']}",
          file=sys.stderr, flush=True)
    print(json.dumps(out, indent=2))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
