"""Live-cluster harnesses for the distributed runtime.

`build_loopback_cluster` mirrors `serving.simulator.build_cluster` exactly
— same construction order, worker ids, backend seeds, profile seeding —
but routes every controller<->worker interaction through the runtime's
wire protocol over deterministic loopback channels. With zero transport
latency the event sequence is *identical* to the in-process path (the
loopback delivers synchronously inside the sender's event), which is what
the decision-trace equivalence test pins down; with latency/jitter/drop
configured it becomes a reproducible network-condition testbed on the
virtual clock.

The returned object is the ordinary `serving.simulator.Cluster`, so
clients, TimeSeries sampling, and telemetry reports all work unchanged;
`cluster.runtime` additionally exposes the server, hosts, and links plus
a `shutdown()` that winds the daemons down gracefully (flushing their
telemetry) and drains the loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.clock import EventLoop, VirtualClock
from repro.core.controller import Controller
from repro.core.scheduler import ClockworkScheduler
from repro.core.worker import ModelDef, Worker
from repro.runtime.client import RemoteClient
from repro.runtime.controller import ControllerServer
from repro.runtime.transport import LoopbackLink
from repro.runtime.worker import WorkerHost
from repro.serving.simulator import (Cluster, make_sim_worker,
                                     place_preload, seed_profiles)
from repro.telemetry.profile_store import ProfileStore
from repro.telemetry.recorder import Recorder


@dataclasses.dataclass
class LoopbackRuntime:
    """Handle to the distributed plumbing behind a loopback Cluster."""
    server: ControllerServer
    hosts: List[WorkerHost]
    links: List[LoopbackLink]
    loop: EventLoop
    # RemoteClients attached via attach_remote_client (third tier)
    clients: List[RemoteClient] = dataclasses.field(default_factory=list)

    def shutdown(self, drain_s: float = 1.0) -> None:
        """Daemon-initiated graceful leave for every worker host (each
        flushes its telemetry buffer first), then drain the loop so all
        in-flight frames land. Virtual-clock only."""
        for h in self.hosts:
            if not h.closed:
                h.shutdown()
        self.loop.run_until(self.loop.now() + drain_s)

    @property
    def dropped_frames(self) -> int:
        return sum(l.dropped for l in self.links)


def build_loopback_cluster(
        models: Dict[str, ModelDef], *, n_workers: int = 1,
        gpus_per_worker: int = 1, scheduler=None,
        device_memory: float = 32e9, host_to_dev_bw: float = 12.3e9,
        noise: float = 0.0003, spike_prob: float = 0.0,
        spike_scale: float = 5.0, action_delay: float = 0.0005,
        seed: int = 0, preload: Optional[List[str]] = None,
        profile_store: Optional[ProfileStore] = None,
        recorder: Optional[Recorder] = None,
        latency: float = 0.0, jitter: float = 0.0, drop: float = 0.0,
        transport_seed: int = 12345,
        telemetry_interval: Optional[float] = 1.0,
        telemetry_batch: int = 16,
        fold_net_delay: bool = True) -> Cluster:
    """`build_cluster`, but with the process boundary in the middle.

    latency/jitter/drop configure the loopback links (seeded, virtual-
    clock deterministic). `fold_net_delay` seeds each worker mirror's
    `net_delay` with the known mean one-way delay so the scheduler's
    action windows account for the network, as the ControllerServer's
    RTT estimation would in a real deployment.
    """
    loop = EventLoop(VirtualClock())
    sched = scheduler if scheduler is not None else ClockworkScheduler()
    controller = Controller(loop, models, sched, action_delay=action_delay,
                            recorder=recorder)
    # estimation off: loopback delay is configured, not measured, so the
    # run stays bit-deterministic (and bit-identical to in-process at 0)
    server = ControllerServer(controller, estimate_net_delay=False)
    profiles = profile_store.seed_dict() if profile_store is not None \
        else seed_profiles(models, host_to_dev_bw)
    workers: List[Worker] = []
    hosts: List[WorkerHost] = []
    links: List[LoopbackLink] = []
    for i in range(n_workers):
        w = make_sim_worker(i, loop, models,
                            gpus_per_worker=gpus_per_worker,
                            device_memory=device_memory,
                            host_to_dev_bw=host_to_dev_bw, noise=noise,
                            spike_prob=spike_prob,
                            spike_scale=spike_scale, seed=seed)
        link = LoopbackLink(loop, latency=latency, jitter=jitter, drop=drop,
                            seed=transport_seed + i)
        server.adopt(link.a)
        host = WorkerHost(w, link.b,
                          profiles=profiles if i == 0 else None,
                          telemetry_interval=telemetry_interval,
                          telemetry_batch=telemetry_batch)
        host.register()
        workers.append(w)
        hosts.append(host)
        links.append(link)
    if latency > 0.0 or jitter > 0.0:
        # registration frames are in flight: complete membership before
        # the workload starts (advances virtual time by <= latency+jitter)
        loop.run_until(loop.now() + latency + jitter + 1e-9)
    mean_net = latency + 0.5 * jitter
    if fold_net_delay and mean_net > 0.0:
        for m in controller.workers.values():
            m.net_delay = mean_net
    place_preload(controller, workers, models, preload)
    return Cluster(loop=loop, controller=controller, workers=workers,
                   models=models,
                   runtime=LoopbackRuntime(server=server, hosts=hosts,
                                           links=links, loop=loop))


def attach_remote_client(cluster: Cluster, *, latency: float = 0.0,
                         jitter: float = 0.0, drop: float = 0.0,
                         transport_seed: int = 54321,
                         recorder: Optional[Recorder] = None
                         ) -> RemoteClient:
    """Connect a `RemoteClient` to a loopback cluster's ControllerServer
    over its own seeded LoopbackLink — the client tier of the paper's
    topology, on the virtual clock.

    At zero latency the SUBMIT/RESPONSE round-trip is synchronous inside
    the sender's event, so a seeded workload driven through the returned
    client produces a decision trace *identical* to in-process
    `attach_clients` (pinned by tests/test_client.py). With latency/
    jitter configured it reproduces client-side network conditions
    deterministically.
    """
    rt = cluster.runtime
    if not isinstance(rt, LoopbackRuntime):
        raise ValueError("attach_remote_client needs a loopback cluster "
                         "(build_cluster(transport='loopback'))")
    link = LoopbackLink(rt.loop, latency=latency, jitter=jitter, drop=drop,
                        seed=transport_seed)
    rt.server.adopt(link.a)
    client = RemoteClient(rt.loop, link.b, recorder=recorder)
    rt.links.append(link)
    rt.clients.append(client)
    return client
