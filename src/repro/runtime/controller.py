"""Controller-side distributed runtime: membership + remote worker stubs.

`ControllerServer` adopts transport channels (loopback or TCP) and speaks
the protocol's membership handshake. A registering worker daemon becomes a
`RemoteWorkerStub` — an object that looks exactly like a core `Worker` to
the unmodified `Controller` (worker_id, pagecache geometry, `receive`,
`ping`, `on_result`), so the controller's mirrors, scheduler, heartbeats,
and missed-result detector all work unchanged across the process boundary.

Per-worker network latency: every heartbeat PONG carries the PING's send
stamp back, the server computes the RTT and folds RTT/2 into the worker
mirror's `net_delay` (EWMA, `Controller.observe_net_delay`), which widens
the scheduler's expected-start and missed-result windows for that worker —
the paper's §5 treatment of network delay. The loopback harness disables
estimation (`estimate_net_delay=False`) and folds its *configured* latency
instead, keeping virtual-clock runs deterministic.

Channels whose first message is SUBMIT instead of HELLO are request
clients: decoded Requests enter `Controller.on_request` and their
completions return as RESPONSE frames. Client channels are tracked with
their in-flight request ids so a disconnect reclaims everything: the ids
are purged from `_req_origin` and responses for a departed client are
dropped instead of sent into a closed pipe.

Hardening: every frame handler runs behind `_frame_handler`, which turns
a `ProtocolError` (bad version, malformed frame) or a codec-level
KeyError/ValueError/TypeError into a logged close of the *offending
channel* — a garbage frame from one peer must never crash the shared
controller event loop.
"""
from __future__ import annotations

import itertools
import logging
from typing import Callable, Dict, List, Optional, Set

from repro.core.actions import Request
from repro.core.controller import Controller
from repro.runtime import protocol
from repro.runtime.transport import Channel, TcpServer

log = logging.getLogger("repro.runtime")


class _PageSpec:
    """Minimal pagecache geometry stand-in (what WorkerMirror reads)."""

    __slots__ = ("total_pages", "page_bytes")

    def __init__(self, total_pages: int, page_bytes: int):
        self.total_pages = total_pages
        self.page_bytes = page_bytes


class RemoteWorkerStub:
    """Controller-side proxy for a worker daemon reachable over a Channel.

    Duck-types the parts of `core.worker.Worker` the Controller touches.
    """

    def __init__(self, channel: Channel, worker_id: str,
                 gpu_specs: List[dict], server: "ControllerServer"):
        self.channel = channel
        self.worker_id = worker_id
        self.pagecaches = [_PageSpec(g["total_pages"], g["page_bytes"])
                           for g in gpu_specs]
        self.server = server
        self.alive = True
        self.graceful = False           # set before an expected disconnect
        self.on_result: Optional[Callable] = None   # set by add_worker
        self._ping_seq = itertools.count()
        self._pings: Dict[int, tuple] = {}   # seq -> (reply, t_sent)

    # ------------------------------------------------- Worker-facing API
    def receive(self, action) -> None:
        if self.alive:
            self.channel.send(protocol.action_msg(action))

    def ping(self, reply: Callable[[], None]) -> None:
        if not self.alive:
            return
        seq = next(self._ping_seq)
        t = self.server.controller.loop.now()
        self._pings[seq] = (reply, t)
        self.channel.send(protocol.ping(seq, t))

    # ---------------------------------------------------- frame handling
    def handle(self, msg: dict) -> None:
        # wire decoding goes through protocol.field/decode, which turn
        # structural garbage into ProtocolError for the server's frame
        # guard; the controller calls that follow run unguarded, so an
        # internal bug still fails loudly instead of being misread as a
        # bad frame from this worker
        kind = msg.get("kind")
        c = self.server.controller
        if kind == "result":
            r = protocol.decode(protocol.result_from_wire,
                                protocol.field(msg, "result"))
            if self.on_result is not None:
                self.on_result(r)
        elif kind == "pong":
            seq = protocol.field(msg, "seq")
            if isinstance(seq, (dict, list)):
                raise protocol.ProtocolError("pong seq is unhashable")
            entry = self._pings.pop(seq, None)
            if entry is None:
                return
            reply, t_sent = entry
            if self.server.estimate_net_delay:
                # the PONG echoes the worker's reply turnaround (`hold`):
                # subtracting it leaves the pure network round-trip, so a
                # slow-to-answer worker no longer inflates its net_delay
                hold = protocol.decode(float, msg.get("hold", 0.0))
                rtt = max(0.0, c.loop.now() - t_sent - hold)
                c.observe_net_delay(self.worker_id, rtt)
            reply()
        elif kind == "telemetry":
            rec = c.recorder
            for wire in protocol.decode(tuple, msg.get("gauges", ())):
                g = protocol.decode(protocol.gauge_from_wire, wire)
                rec.record_gauge(g.name, g.t, g.value)
        elif kind == "sync":
            self.channel.send(protocol.sync_ack(protocol.field(msg, "t0"),
                                                c.loop.now()))
        elif kind == "goodbye":
            self.graceful = True
            self.alive = False
            self.channel.send(protocol.goodbye_ack())
            c.remove_worker(self.worker_id)
        # unknown kinds are ignored (forward compatibility within v1)

    def handle_close(self) -> None:
        was_alive = self.alive
        self.alive = False
        if was_alive and not self.graceful:
            self.server.controller.worker_failed(self.worker_id)


class ControllerServer:
    """Adopts channels, runs the membership handshake, and owns the
    controller-side ends of all worker/client connections."""

    def __init__(self, controller: Controller, *,
                 estimate_net_delay: bool = True):
        self.controller = controller
        self.estimate_net_delay = estimate_net_delay
        self.stubs: Dict[str, RemoteWorkerStub] = {}
        # client channel -> its in-flight local request ids; removed (with
        # the ids purged from _req_origin) when the channel closes
        self.clients: Dict[Channel, Set[int]] = {}
        # local request id -> (origin channel, the client's own id)
        self._req_origin: Dict[int, tuple] = {}
        self._tcp: Optional[TcpServer] = None
        self.closed = False
        self.bad_frames = 0          # channels closed on malformed input

        prev = controller.on_response

        def fan(req):
            if prev:
                prev(req)
            origin = self._req_origin.pop(req.id, None)
            if origin is not None:
                ch, remote_id = origin
                inflight = self.clients.get(ch)
                if inflight is None:
                    return           # client left; drop, don't send
                inflight.discard(req.id)
                ch.send(protocol.response_msg(req, override_id=remote_id))

        controller.on_response = fan

    # ------------------------------------------------------- channel intake
    def _frame_handler(self, channel: Channel,
                       fn: Callable[[dict], None]) -> Callable[[dict], None]:
        """Wrap a per-frame handler so malformed input closes the offending
        channel instead of raising into the shared event loop. Handlers
        funnel all wire decoding through protocol.field/decode, so only
        ProtocolError means "bad frame" — an internal controller bug still
        propagates loudly rather than being pinned on an innocent peer."""
        def handle(msg: dict) -> None:
            try:
                fn(msg)
            except protocol.ProtocolError as e:
                self.bad_frames += 1
                log.warning("closing channel after bad frame "
                            "(kind=%r): %s", msg.get("kind"), e)
                channel.close()
        return handle

    def adopt(self, channel: Channel) -> None:
        """Take ownership of a fresh channel; the first frame decides
        whether it is a worker (HELLO) or a request client (SUBMIT)."""
        channel.on_message = self._frame_handler(
            channel, lambda msg: self._first_frame(channel, msg))
        channel.on_close = lambda: None

    def _first_frame(self, channel: Channel, msg: dict) -> None:
        protocol.check_version(msg)
        kind = msg.get("kind")
        if kind == "hello":
            self._register_worker(channel, msg)
        elif kind == "submit":
            self.clients[channel] = set()
            channel.on_message = self._frame_handler(
                channel, lambda m: self._client_frame(channel, m))
            channel.on_close = lambda: self._client_closed(channel)
            self._client_frame(channel, msg)
        else:
            channel.close()

    def _register_worker(self, channel: Channel, msg: dict) -> None:
        # decode/validate the whole HELLO before touching controller state
        wid = protocol.decode(str, protocol.field(msg, "worker_id"))
        gpu_specs = protocol.decode(protocol.gpus_from_hello, msg)
        profiles = protocol.decode(protocol.profiles_from_hello, msg)
        if wid in self.controller.workers:
            # a stale registration (daemon restart): retire the old mirror
            # gracefully — outstanding work is requeued, but a planned
            # replacement must not count as a dead worker
            old = self.stubs.get(wid)
            if old is not None:
                old.graceful = True
                old.alive = False
                old.channel.close()
            self.controller.remove_worker(wid)
        stub = RemoteWorkerStub(channel, wid, gpu_specs, self)
        self.stubs[wid] = stub
        channel.on_message = self._frame_handler(channel, stub.handle)
        channel.on_close = stub.handle_close
        self.controller.add_worker(stub, profiles)
        channel.send(protocol.welcome(
            wid, self.controller.heartbeat_interval))

    def _client_frame(self, channel: Channel, msg: dict) -> None:
        if msg.get("kind") == "submit":
            wire = protocol.decode(protocol.request_from_wire,
                                   protocol.field(msg, "request"))
            if wire.model_id not in self.controller.models:
                # unknown model: reject on the spot — the name must never
                # enter the scheduler (its queues are a defaultdict, and a
                # bogus key would only blow up later, outside the guard)
                wire.status = "rejected"
                wire.completion = self.controller.loop.now()
                channel.send(protocol.response_msg(wire))
                return
            # re-issue the id: client-process id counters collide with each
            # other and with controller-local requests. The remote arrival
            # stamp is likewise meaningless on this clock — admission time
            # is the arrival. The RESPONSE echoes the client's own id back.
            req = Request(model_id=wire.model_id,
                          arrival=self.controller.loop.now(),
                          slo=wire.slo, batchable=wire.batchable)
            self._req_origin[req.id] = (channel, wire.id)
            self.clients[channel].add(req.id)
            self.controller.on_request(req)

    def _client_closed(self, channel: Channel) -> None:
        """Reclaim a departed client: requests still in flight keep being
        served (the scheduler already committed to them) but their origin
        entries go away, so completions are counted and dropped rather
        than sent into a closed channel."""
        inflight = self.clients.pop(channel, None)
        if inflight:
            for rid in inflight:
                self._req_origin.pop(rid, None)

    # -------------------------------------------------------------- TCP
    def listen_tcp(self, host: str, port: int,
                   post: Callable[[Callable[[], None]], None]) -> int:
        """Start accepting worker/client connections; returns bound port."""
        self._tcp = TcpServer(host, port, post, self.adopt)
        return self._tcp.port

    # --------------------------------------------------------- lifecycle
    def shutdown(self) -> None:
        """Graceful stop: tell every live daemon to wind down (they flush
        telemetry and exit), then stop accepting."""
        if self.closed:
            return
        self.closed = True
        for stub in self.stubs.values():
            if stub.alive:
                stub.graceful = True
                stub.channel.send(protocol.goodbye("controller shutdown"))
        if self._tcp is not None:
            # keep live channels open: daemons flush telemetry, ack, and
            # hang up themselves; we only stop accepting new ones
            self._tcp.close(close_channels=False)
