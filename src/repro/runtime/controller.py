"""Controller-side distributed runtime: membership + remote worker stubs.

`ControllerServer` adopts transport channels (loopback or TCP) and speaks
the protocol's membership handshake. A registering worker daemon becomes a
`RemoteWorkerStub` — an object that looks exactly like a core `Worker` to
the unmodified `Controller` (worker_id, pagecache geometry, `receive`,
`ping`, `on_result`), so the controller's mirrors, scheduler, heartbeats,
and missed-result detector all work unchanged across the process boundary.

Per-worker network latency: every heartbeat PONG carries the PING's send
stamp back, the server computes the RTT and folds RTT/2 into the worker
mirror's `net_delay` (EWMA, `Controller.observe_net_delay`), which widens
the scheduler's expected-start and missed-result windows for that worker —
the paper's §5 treatment of network delay. The loopback harness disables
estimation (`estimate_net_delay=False`) and folds its *configured* latency
instead, keeping virtual-clock runs deterministic.

Channels whose first message is SUBMIT instead of HELLO are request
clients: decoded Requests enter `Controller.on_request` and their
completions return as RESPONSE frames.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional

from repro.core.actions import Request
from repro.core.controller import Controller
from repro.runtime import protocol
from repro.runtime.transport import Channel, TcpServer


class _PageSpec:
    """Minimal pagecache geometry stand-in (what WorkerMirror reads)."""

    __slots__ = ("total_pages", "page_bytes")

    def __init__(self, total_pages: int, page_bytes: int):
        self.total_pages = total_pages
        self.page_bytes = page_bytes


class RemoteWorkerStub:
    """Controller-side proxy for a worker daemon reachable over a Channel.

    Duck-types the parts of `core.worker.Worker` the Controller touches.
    """

    def __init__(self, channel: Channel, worker_id: str,
                 gpu_specs: List[dict], server: "ControllerServer"):
        self.channel = channel
        self.worker_id = worker_id
        self.pagecaches = [_PageSpec(g["total_pages"], g["page_bytes"])
                           for g in gpu_specs]
        self.server = server
        self.alive = True
        self.graceful = False           # set before an expected disconnect
        self.on_result: Optional[Callable] = None   # set by add_worker
        self._ping_seq = itertools.count()
        self._pings: Dict[int, tuple] = {}   # seq -> (reply, t_sent)

    # ------------------------------------------------- Worker-facing API
    def receive(self, action) -> None:
        if self.alive:
            self.channel.send(protocol.action_msg(action))

    def ping(self, reply: Callable[[], None]) -> None:
        if not self.alive:
            return
        seq = next(self._ping_seq)
        t = self.server.controller.loop.now()
        self._pings[seq] = (reply, t)
        self.channel.send(protocol.ping(seq, t))

    # ---------------------------------------------------- frame handling
    def handle(self, msg: dict) -> None:
        kind = msg.get("kind")
        c = self.server.controller
        if kind == "result":
            r = protocol.result_from_wire(msg["result"])
            if self.on_result is not None:
                self.on_result(r)
        elif kind == "pong":
            entry = self._pings.pop(msg["seq"], None)
            if entry is None:
                return
            reply, t_sent = entry
            if self.server.estimate_net_delay:
                rtt = c.loop.now() - t_sent
                # subtract the worker's own reply turnaround? the stamp we
                # echo is the send time, so rtt includes the worker's
                # result_delay — the same asymmetry the in-process path has
                c.observe_net_delay(self.worker_id, rtt)
            reply()
        elif kind == "telemetry":
            rec = c.recorder
            for wire in msg.get("gauges", ()):
                g = protocol.gauge_from_wire(wire)
                rec.record_gauge(g.name, g.t, g.value)
        elif kind == "sync":
            self.channel.send(protocol.sync_ack(msg["t0"], c.loop.now()))
        elif kind == "goodbye":
            self.graceful = True
            self.alive = False
            self.channel.send(protocol.goodbye_ack())
            c.remove_worker(self.worker_id)
        # unknown kinds are ignored (forward compatibility within v1)

    def handle_close(self) -> None:
        was_alive = self.alive
        self.alive = False
        if was_alive and not self.graceful:
            self.server.controller.worker_failed(self.worker_id)


class ControllerServer:
    """Adopts channels, runs the membership handshake, and owns the
    controller-side ends of all worker/client connections."""

    def __init__(self, controller: Controller, *,
                 estimate_net_delay: bool = True):
        self.controller = controller
        self.estimate_net_delay = estimate_net_delay
        self.stubs: Dict[str, RemoteWorkerStub] = {}
        self.clients: List[Channel] = []
        # local request id -> (origin channel, the client's own id)
        self._req_origin: Dict[int, tuple] = {}
        self._tcp: Optional[TcpServer] = None
        self.closed = False

        prev = controller.on_response

        def fan(req):
            if prev:
                prev(req)
            origin = self._req_origin.pop(req.id, None)
            if origin is not None:
                ch, remote_id = origin
                ch.send(protocol.response_msg(req, override_id=remote_id))

        controller.on_response = fan

    # ------------------------------------------------------- channel intake
    def adopt(self, channel: Channel) -> None:
        """Take ownership of a fresh channel; the first frame decides
        whether it is a worker (HELLO) or a request client (SUBMIT)."""
        channel.on_message = lambda msg: self._first_frame(channel, msg)
        channel.on_close = lambda: None

    def _first_frame(self, channel: Channel, msg: dict) -> None:
        protocol.check_version(msg)
        kind = msg.get("kind")
        if kind == "hello":
            self._register_worker(channel, msg)
        elif kind == "submit":
            self.clients.append(channel)
            channel.on_message = lambda m: self._client_frame(channel, m)
            self._client_frame(channel, msg)
        else:
            channel.close()

    def _register_worker(self, channel: Channel, msg: dict) -> None:
        wid = msg["worker_id"]
        if wid in self.controller.workers:
            # a stale registration (daemon restart): retire the old mirror
            # gracefully — outstanding work is requeued, but a planned
            # replacement must not count as a dead worker
            old = self.stubs.get(wid)
            if old is not None:
                old.graceful = True
                old.alive = False
                old.channel.close()
            self.controller.remove_worker(wid)
        stub = RemoteWorkerStub(channel, wid, msg["gpus"], self)
        self.stubs[wid] = stub
        channel.on_message = stub.handle
        channel.on_close = stub.handle_close
        self.controller.add_worker(stub, protocol.profiles_from_hello(msg))
        channel.send(protocol.welcome(
            wid, self.controller.heartbeat_interval))

    def _client_frame(self, channel: Channel, msg: dict) -> None:
        if msg.get("kind") == "submit":
            wire = protocol.request_from_wire(msg["request"])
            # re-issue the id: client-process id counters collide with each
            # other and with controller-local requests. The remote arrival
            # stamp is likewise meaningless on this clock — admission time
            # is the arrival. The RESPONSE echoes the client's own id back.
            req = Request(model_id=wire.model_id,
                          arrival=self.controller.loop.now(),
                          slo=wire.slo, batchable=wire.batchable)
            self._req_origin[req.id] = (channel, wire.id)
            self.controller.on_request(req)

    # -------------------------------------------------------------- TCP
    def listen_tcp(self, host: str, port: int,
                   post: Callable[[Callable[[], None]], None]) -> int:
        """Start accepting worker/client connections; returns bound port."""
        self._tcp = TcpServer(host, port, post, self.adopt)
        return self._tcp.port

    # --------------------------------------------------------- lifecycle
    def shutdown(self) -> None:
        """Graceful stop: tell every live daemon to wind down (they flush
        telemetry and exit), then stop accepting."""
        if self.closed:
            return
        self.closed = True
        for stub in self.stubs.values():
            if stub.alive:
                stub.graceful = True
                stub.channel.send(protocol.goodbye("controller shutdown"))
        if self._tcp is not None:
            # keep live channels open: daemons flush telemetry, ack, and
            # hang up themselves; we only stop accepting new ones
            self._tcp.close(close_channels=False)
