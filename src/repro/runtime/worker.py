"""Worker-side distributed runtime: WorkerHost bridge + WorkerDaemon CLI.

`WorkerHost` wraps an existing `core.worker.Worker` (with any backend —
SimBackend or the real JAX engine runners) and bridges it over a Channel:

* ACTION frames are decoded and their `[earliest, latest]` windows mapped
  from the controller's clock into the local clock (`ClockSync`) before
  entering the worker's executors — so window enforcement still means what
  the controller intended despite clock skew;
* local Results get their timestamps mapped *back* onto the controller's
  timeline before the RESULT frame is sent — cross-boundary span
  stitching: the controller's RequestSpans and ActionRecords carry
  worker-side stamps on one consistent clock;
* PING is answered like the in-process `Worker.ping` (after
  `result_delay`, only while alive), so heartbeat semantics match;
* worker-side telemetry (per-executor busy-seconds and queue depth, clock
  offset) is sampled periodically into a buffer and flushed as TELEMETRY
  frames when the buffer fills — and always on `shutdown()`, so a
  daemon's final samples are never lost (`telemetry_report` counts match
  single-process runs).

`python -m repro.runtime.worker --controller HOST:PORT ...` runs the
daemon: a RealClock EventLoop + RealtimePump, a SimBackend-backed Worker
over the Table-1 demo model set, and a TCP channel to the controller.
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
from typing import List, Optional

from repro.core.clock import EventLoop, RealClock, RealtimePump
from repro.core.worker import Worker
from repro.runtime import protocol
from repro.runtime.transport import Channel, tcp_connect
from repro.telemetry.events import GaugeSample
from repro.telemetry.recorder import Recorder


class ClockSync:
    """Cristian-style offset estimation between this process's loop clock
    and the controller's: `remote ≈ local + offset`. The minimum-RTT
    exchange wins (least queueing distortion). With no observations the
    sync is the identity — exactly right for loopback channels that share
    one clock."""

    def __init__(self):
        self.offset = 0.0
        self.best_rtt = float("inf")
        self.samples = 0

    def observe(self, t0_local: float, t_remote: float,
                t1_local: float) -> float:
        rtt = max(0.0, t1_local - t0_local)
        self.samples += 1
        if rtt <= self.best_rtt:
            self.best_rtt = rtt
            self.offset = t_remote + rtt / 2.0 - t1_local
        return rtt

    def to_remote(self, t_local: float) -> float:
        return t_local + self.offset

    def to_local(self, t_remote: float) -> float:
        return t_remote - self.offset


class WorkerHost:
    """Daemon-side bridge between a core Worker and a Channel."""

    def __init__(self, worker: Worker, channel: Channel, *,
                 profiles: Optional[dict] = None,
                 sync_interval: Optional[float] = None,
                 telemetry_interval: Optional[float] = 1.0,
                 telemetry_batch: int = 16,
                 recorder: Optional[Recorder] = None,
                 on_shutdown=None):
        self.worker = worker
        self.loop = worker.loop
        self.channel = channel
        self.sync = ClockSync()
        self.sync_interval = sync_interval
        self.telemetry_interval = telemetry_interval
        self.telemetry_batch = telemetry_batch
        self.recorder = recorder        # optional local (streaming) sink
        self.on_shutdown = on_shutdown  # called once fully closed
        self._profiles = profiles
        self._pending: List[GaugeSample] = []
        self.registered = False
        self.closed = False
        self._goodbye_sent = False
        self.telemetry_flushes = 0
        worker.on_result = self._on_local_result
        channel.on_message = self._on_message
        channel.on_close = self._on_channel_close

    # ------------------------------------------------------ registration
    def register(self) -> None:
        spec = self.worker.spec()
        self.channel.send(protocol.hello(spec["worker_id"], spec["gpus"],
                                         self._profiles))
        if self.sync_interval:
            self._sync_tick()
        if self.telemetry_interval:
            self.loop.schedule_in(self.telemetry_interval,
                                  self._telemetry_tick)

    # ------------------------------------------------------- clock sync
    def _sync_tick(self) -> None:
        if self.closed:
            return
        self.channel.send(protocol.sync(self.loop.now()))
        self.loop.schedule_in(self.sync_interval, self._sync_tick)

    # ---------------------------------------------------------- inbound
    def _on_message(self, msg: dict) -> None:
        kind = msg.get("kind")
        if kind == "action":
            a = protocol.action_from_wire(msg["action"])
            a.earliest = self.sync.to_local(a.earliest)
            a.latest = self.sync.to_local(a.latest)
            self.worker.receive(a)
        elif kind == "ping":
            if self.worker.alive:
                t_recv = self.loop.now()

                def reply(seq=msg["seq"], t_sent=msg["t_sent"],
                          t_recv=t_recv):
                    # echo the actual turnaround so the controller's RTT
                    # measurement excludes our reply delay
                    hold = self.loop.now() - t_recv
                    self.channel.send(protocol.pong(seq, t_sent, hold))

                self.loop.schedule_in(self.worker.result_delay, reply)
        elif kind == "sync_ack":
            self.sync.observe(msg["t0"], msg["t_remote"], self.loop.now())
        elif kind == "welcome":
            protocol.check_version(msg)
            self.registered = True
        elif kind == "goodbye":
            # controller-initiated wind-down: flush, ack, stop — but leave
            # the pipe open: the flush/ack frames may still be in flight
            # (loopback latency schedules them; TCP buffers them) and
            # closing here would tear them down. The transport closes when
            # the process exits / the peer hangs up.
            self.flush_telemetry(sample_first=True)
            self.channel.send(protocol.goodbye_ack())
            self.closed = True
            if self.on_shutdown is not None:
                self.on_shutdown()
        elif kind == "goodbye_ack":
            self._finish_close()

    # --------------------------------------------------------- outbound
    def _on_local_result(self, r) -> None:
        if self.closed:
            return
        to_r = self.sync.to_remote
        wire = dataclasses.replace(
            r, t_start=to_r(r.t_start), t_end=to_r(r.t_end),
            t_received=to_r(r.t_received))
        self.channel.send(protocol.result_msg(wire))

    # -------------------------------------------------------- telemetry
    def _telemetry_tick(self) -> None:
        if self.closed:
            return
        self.sample_telemetry()
        if len(self._pending) >= self.telemetry_batch:
            self.flush_telemetry()
        self.loop.schedule_in(self.telemetry_interval, self._telemetry_tick)

    def sample_telemetry(self) -> None:
        """Append one round of worker-side gauges (controller timeline)."""
        now_r = self.sync.to_remote(self.loop.now())
        wid = self.worker.worker_id
        add = self._pending.append
        for (g, lane), ex in self.worker.execs.items():
            add(GaugeSample(name=f"worker/{wid}/gpu{g}/{lane}/busy_s",
                            t=now_r, value=ex.total_busy))
            add(GaugeSample(name=f"worker/{wid}/gpu{g}/{lane}/queue_depth",
                            t=now_r, value=float(len(ex.q))))
        add(GaugeSample(name=f"worker/{wid}/clock_offset_s", t=now_r,
                        value=self.sync.offset))

    def flush_telemetry(self, sample_first: bool = False) -> None:
        """Ship buffered gauges. Called when the buffer fills and — the
        part long-running daemons rely on — unconditionally at shutdown,
        so in-flight telemetry is never dropped."""
        if sample_first:
            self.sample_telemetry()
        if self.closed or not self._pending:
            return
        if self.recorder is not None:
            for g in self._pending:
                self.recorder.record_gauge(g.name, g.t, g.value)
        self.channel.send(protocol.telemetry_msg(self._pending))
        self._pending = []
        self.telemetry_flushes += 1

    # --------------------------------------------------------- shutdown
    def shutdown(self, reason: str = "worker shutdown") -> None:
        """Graceful daemon-initiated leave: flush telemetry, then GOODBYE
        (the controller re-queues outstanding work and drops the mirror).
        The channel closes on GOODBYE_ACK or transport teardown."""
        if self.closed or self._goodbye_sent:
            return
        self.flush_telemetry(sample_first=True)
        self._goodbye_sent = True
        self.channel.send(protocol.goodbye(reason))

    def _finish_close(self) -> None:
        if self.closed:
            return
        self.closed = True
        self.channel.close()
        if self.on_shutdown is not None:
            self.on_shutdown()

    def _on_channel_close(self) -> None:
        if not self.closed:
            self.closed = True
            if self.on_shutdown is not None:
                self.on_shutdown()


# ----------------------------------------------------------------- daemon
def demo_models(n_models: int):
    """The Table-1-derived model set both sides of the TCP demo build —
    the daemon's ground truth and the controller's model registry must
    name the same models."""
    from repro.serving.simulator import PAPER_TABLE1, table1_modeldef
    fams = list(PAPER_TABLE1)
    return {f"m{i}": table1_modeldef(f"m{i}", family=fams[i % len(fams)])
            for i in range(n_models)}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.runtime.worker",
        description="Clockwork worker daemon: registers with a controller "
                    "over TCP and executes actions on the local backend.")
    p.add_argument("--controller", required=True, metavar="HOST:PORT")
    p.add_argument("--worker-id", required=True)
    p.add_argument("--n-models", type=int, default=4,
                   help="size of the shared Table-1 demo model set")
    p.add_argument("--gpus", type=int, default=1)
    p.add_argument("--memory-gb", type=float, default=32.0)
    p.add_argument("--noise", type=float, default=0.0003)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--duration", type=float, default=None,
                   help="exit after this many seconds (default: run until "
                        "the controller says goodbye or SIGTERM)")
    p.add_argument("--sync-interval", type=float, default=1.0)
    p.add_argument("--telemetry-interval", type=float, default=1.0)
    p.add_argument("--no-seed-profiles", action="store_true",
                   help="do not send Table-1 seed profiles in HELLO")
    p.add_argument("--telemetry-jsonl", default=None,
                   help="stream worker-side telemetry to this JSONL file")
    p.add_argument("--rotate-bytes", type=int, default=None,
                   help="rotate the telemetry JSONL when it exceeds this")
    args = p.parse_args(argv)

    host, _, port = args.controller.rpartition(":")
    models = demo_models(args.n_models)

    from repro.core.worker import SimBackend
    loop = EventLoop(RealClock())
    pump = RealtimePump(loop)
    backend = SimBackend(noise=args.noise, seed=args.seed)
    worker = Worker(args.worker_id, loop, backend, models,
                    n_gpus=args.gpus,
                    device_memory_bytes=args.memory_gb * 1e9)

    recorder = None
    if args.telemetry_jsonl:
        recorder = Recorder()
        recorder.stream_to(args.telemetry_jsonl,
                           rotate_bytes=args.rotate_bytes)

    profiles = None
    if not args.no_seed_profiles:
        from repro.serving.simulator import seed_profiles
        profiles = seed_profiles(models, backend.host_to_dev_bw)

    channel = tcp_connect(host, int(port), pump.post)
    hostside = WorkerHost(worker, channel, profiles=profiles,
                          sync_interval=args.sync_interval,
                          telemetry_interval=args.telemetry_interval,
                          recorder=recorder, on_shutdown=pump.stop)

    def request_shutdown(*_sig):
        pump.post(hostside.shutdown)

    signal.signal(signal.SIGTERM, request_shutdown)
    signal.signal(signal.SIGINT, request_shutdown)

    pump.post(hostside.register)
    pump.run(until=lambda: hostside.closed, timeout=args.duration)
    if not hostside.closed:
        # duration elapsed: leave gracefully, give the ack a moment
        hostside.shutdown("duration elapsed")
        pump.run(until=lambda: hostside.closed, timeout=5.0)
    if recorder is not None:
        recorder.close_stream()
    return 0


if __name__ == "__main__":
    sys.exit(main())
