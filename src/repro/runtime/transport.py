"""Pluggable transports for the distributed runtime (DESIGN.md §5).

A `Channel` is one endpoint of a bidirectional, ordered message pipe:
`send(msg)` ships a protocol dict; incoming messages arrive via the
`on_message` callback, connection teardown via `on_close`. Two
implementations:

* `LoopbackLink` — an in-process pair of channels wired through the
  shared EventLoop. Every message still round-trips through the real
  frame codec (encode -> bytes -> decode), so the wire format is
  exercised, but delivery is deterministic: with zero configured
  latency/jitter/drop, delivery is synchronous inside the sender's event,
  which makes the event sequence *identical* to the in-process path (the
  decision-equivalence tests rely on this). With latency/jitter/drop
  configured, delivery is scheduled on the loop with a seeded RNG —
  virtual-clock compatible and reproducible. FIFO order is preserved per
  direction even under jitter (a real TCP stream never reorders).

* `TcpChannel`/`TcpServer` — a real socket transport for multi-process
  runs. Reader threads never touch the event loop: they `post()` decoded
  messages through a `RealtimePump` (core/clock.py) onto the loop thread.
"""
from __future__ import annotations

import random
import socket
import threading
from typing import Callable, List, Optional

from repro.runtime.protocol import FrameDecoder, ProtocolError, encode_frame

# frame kinds eligible for loopback drop injection: losing serving traffic
# exercises the missed-result detector; losing membership/liveness frames
# would just wedge the handshake, which isn't the failure mode under test
DROPPABLE_KINDS = ("action", "result")


class Channel:
    """One endpoint of an ordered message pipe."""

    def __init__(self):
        self.on_message: Optional[Callable[[dict], None]] = None
        self.on_close: Optional[Callable[[], None]] = None

    def send(self, msg: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError


# ---------------------------------------------------------------- loopback
class _LoopbackEndpoint(Channel):
    def __init__(self, link: "LoopbackLink", side: int):
        super().__init__()
        self._link = link
        self._side = side

    def send(self, msg: dict) -> None:
        self._link._send(self._side, msg)

    def close(self) -> None:
        self._link.close()


class LoopbackLink:
    """Deterministic in-process channel pair over a shared EventLoop.

    latency: fixed one-way delay (seconds); jitter: extra uniform [0, j)
    delay per frame; drop: per-frame drop probability (serving frames
    only, see DROPPABLE_KINDS). All randomness comes from one seeded RNG,
    so runs are bit-reproducible under the virtual clock.
    """

    def __init__(self, loop, *, latency: float = 0.0, jitter: float = 0.0,
                 drop: float = 0.0, seed: int = 0):
        self.loop = loop
        self.latency = latency
        self.jitter = jitter
        self.drop = drop
        self.rng = random.Random(seed)
        self.a = _LoopbackEndpoint(self, 0)   # controller-side by convention
        self.b = _LoopbackEndpoint(self, 1)   # worker-side by convention
        self._peer = {0: self.b, 1: self.a}
        # per-direction FIFO floor: delivery never before the previous frame
        self._fifo_floor = [0.0, 0.0]
        self.closed = False
        self.dropped = 0
        self.frames = 0

    def _send(self, side: int, msg: dict) -> None:
        if self.closed:
            return
        # full codec round-trip: the loopback path must exercise exactly
        # the bytes the TCP path would carry
        frames = FrameDecoder().feed(encode_frame(msg))
        if len(frames) != 1:
            raise ProtocolError("loopback frame did not round-trip")
        decoded = frames[0]
        self.frames += 1
        if self.drop and decoded.get("kind") in DROPPABLE_KINDS \
                and self.rng.random() < self.drop:
            self.dropped += 1
            return
        peer = self._peer[side]

        def deliver(msg=decoded, peer=peer):
            if not self.closed and peer.on_message is not None:
                peer.on_message(msg)

        delay = self.latency
        if self.jitter:
            delay += self.jitter * self.rng.random()
        if delay <= 0.0:
            deliver()                 # synchronous: event-sequence neutral
            return
        at = max(self.loop.now() + delay, self._fifo_floor[side])
        self._fifo_floor[side] = at
        self.loop.schedule(at, deliver)

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        for ep in (self.a, self.b):
            if ep.on_close is not None:
                ep.on_close()


# --------------------------------------------------------------------- TCP
class TcpChannel(Channel):
    """Channel over a connected socket. A reader thread decodes frames and
    posts them (via `post`, typically RealtimePump.post) onto the event
    loop thread; send() writes synchronously under a lock."""

    def __init__(self, sock: socket.socket,
                 post: Callable[[Callable[[], None]], None]):
        super().__init__()
        self._sock = sock
        self._post = post
        self._wlock = threading.Lock()
        self._closed = False
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        self._thread = threading.Thread(target=self._read_loop, daemon=True)
        self._thread.start()

    def _read_loop(self) -> None:
        dec = FrameDecoder()
        try:
            while True:
                data = self._sock.recv(65536)
                if not data:
                    break
                for msg in dec.feed(data):
                    self._post(lambda m=msg: self._dispatch(m))
        except (OSError, ProtocolError):
            pass
        self._post(self._dispatch_close)

    def _dispatch(self, msg: dict) -> None:
        if not self._closed and self.on_message is not None:
            self.on_message(msg)

    def _dispatch_close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self.on_close is not None:
            self.on_close()

    def send(self, msg: dict) -> None:
        if self._closed:
            return
        data = encode_frame(msg)
        try:
            with self._wlock:
                self._sock.sendall(data)
        except OSError:
            self._dispatch_close()

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


def tcp_connect(host: str, port: int,
                post: Callable[[Callable[[], None]], None],
                timeout: float = 10.0) -> TcpChannel:
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.settimeout(None)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    ch = TcpChannel(sock, post)
    ch.start()
    return ch


class TcpServer:
    """Listening socket; each accepted connection becomes a TcpChannel
    handed to `on_channel` on the loop thread."""

    def __init__(self, host: str, port: int,
                 post: Callable[[Callable[[], None]], None],
                 on_channel: Callable[[TcpChannel], None]):
        self._post = post
        self._on_channel = on_channel
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.host, self.port = self._sock.getsockname()[:2]
        self._closed = False
        self.channels: List[TcpChannel] = []
        self._thread = threading.Thread(target=self._accept_loop,
                                        daemon=True)
        self._thread.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                break
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            ch = TcpChannel(conn, self._post)
            self.channels.append(ch)

            def adopt(ch=ch):
                self._on_channel(ch)
                ch.start()

            self._post(adopt)

    def close(self, close_channels: bool = True) -> None:
        """Stop accepting. `close_channels=False` leaves live connections
        open — a graceful shutdown wants peers to flush and hang up
        themselves, not to have their final frames torn down."""
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass
        if close_channels:
            for ch in self.channels:
                ch.close()
