"""Distributed serving runtime (DESIGN.md §5).

Lifts the in-process Controller/Worker pair across a process boundary:

* `protocol`  — versioned, length-prefixed JSON wire protocol for
  Request/Action/Result/telemetry traffic plus membership messages.
* `transport` — pluggable Channel abstraction with a deterministic
  in-process loopback (injectable latency/jitter/drop, virtual-clock
  compatible) and a real TCP implementation for multi-process runs.
* `controller` — ControllerServer: worker membership (join/leave,
  heartbeats feeding the missed-result detector) and per-worker network
  latency estimation folded into the scheduler's action windows.
* `worker` — WorkerHost/WorkerDaemon (`python -m repro.runtime.worker`):
  registers with the controller, executes actions via the existing core
  Worker + backends, and streams results + telemetry back.
* `harness` — builds loopback "distributed" clusters that plug into the
  existing simulator Cluster API, and demo model sets shared by both
  sides of the TCP demo.
"""
from repro.runtime.protocol import PROTOCOL_VERSION  # noqa: F401
