"""Distributed serving runtime (DESIGN.md §5).

Lifts the in-process Controller/Worker pair across a process boundary:

* `protocol`  — versioned, length-prefixed JSON wire protocol for
  Request/Action/Result/telemetry traffic plus membership messages.
* `transport` — pluggable Channel abstraction with a deterministic
  in-process loopback (injectable latency/jitter/drop, virtual-clock
  compatible) and a real TCP implementation for multi-process runs.
* `controller` — ControllerServer: worker membership (join/leave,
  heartbeats feeding the missed-result detector) and per-worker network
  latency estimation folded into the scheduler's action windows.
* `worker` — WorkerHost/WorkerDaemon (`python -m repro.runtime.worker`):
  registers with the controller, executes actions via the existing core
  Worker + backends, and streams results + telemetry back.
* `client` — RemoteClient: the SUBMIT/RESPONSE request client with
  client-side send/receive stamps, per-request latency spans in a local
  Recorder, and skew-free network-overhead stitching from the RESPONSE's
  echoed controller stamps.
* `loadgen` — the load-generator process (`python -m
  repro.runtime.loadgen`): drives the seeded serving/workload generators
  through RemoteClients over TCP (optionally multi-process) and reports
  client-observed goodput + latency percentiles — the third tier of the
  paper's topology.
* `harness` — builds loopback "distributed" clusters that plug into the
  existing simulator Cluster API (plus `attach_remote_client` for the
  client tier on the virtual clock), and demo model sets shared by both
  sides of the TCP demo.
"""
from repro.runtime.protocol import PROTOCOL_VERSION  # noqa: F401
