"""Remote request clients: the third tier of the paper's topology.

`RemoteClient` speaks SUBMIT/RESPONSE over any `Channel` (deterministic
loopback in tests and the harness, TCP in the load-generator process).
It is deliberately the same shape as the in-process submission path —
`submit(Request)` in, workload-generator `on_response` callbacks out —
so the generators in `serving/workload.py` drive a remote controller
unchanged, and a zero-latency loopback run is event-for-event identical
to `Cluster.attach_clients`.

Client-side observability: every request gets send/receive stamps on the
*client's* clock and a RequestSpan in a local `Recorder` (arrival,
queued=send, response=receive). The RESPONSE echoes the controller-side
[admission, completion] interval, which `Recorder.span_remote` stamps
onto the span — both remote stamps share the controller clock, so the
span's `net_overhead` (client-observed minus controller-observed
latency) is immune to clock skew. `report()` summarizes through
`telemetry.reports.client_breakdown`; this is the latency the paper's §6
evaluation actually measures — SLO attainment on the *client's* side of
the network.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.actions import Request
from repro.core.clock import EventLoop
from repro.runtime import protocol
from repro.runtime.transport import Channel
from repro.telemetry.recorder import Recorder
from repro.telemetry.reports import client_breakdown, quantile


class RemoteClient:
    """One SUBMIT/RESPONSE connection to a remote controller."""

    def __init__(self, loop: EventLoop, channel: Channel, *,
                 recorder: Optional[Recorder] = None):
        self.loop = loop
        self.channel = channel
        self.recorder = recorder if recorder is not None else Recorder()
        # client request id -> send stamp (client clock)
        self._pending: Dict[int, float] = {}
        self._responders: List[Callable[[Request], None]] = []
        self.sent = 0
        self.lost = 0                   # in flight when the channel died
        self.stats = {"ok": 0, "timeout": 0, "rejected": 0}
        self.latencies: List[float] = []    # client-observed, ok only
        self.closed = False
        channel.on_message = self._on_message
        channel.on_close = self._on_close

    # ----------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        """Send one request; correlation is by the request's own id (the
        controller re-issues ids internally but echoes ours back)."""
        if self.closed:
            return
        t = self.loop.now()
        self._pending[req.id] = t
        self.recorder.span_open(req, queued=t)
        self.sent += 1
        self.channel.send(protocol.submit_msg(req))

    def attach(self, clients) -> None:
        """Register workload generators: anything with `on_response(req)`
        is called for every RESPONSE — mirror of Cluster.attach_clients,
        so closed-loop clients self-clock against the remote controller."""
        self._responders.extend(c.on_response for c in clients
                                if hasattr(c, "on_response"))

    # --------------------------------------------------------- inbound
    def _on_message(self, msg: dict) -> None:
        if msg.get("kind") != "response":
            return                      # forward compatibility within v1
        resp = protocol.request_from_wire(msg["request"])
        t_recv = self.loop.now()
        t_sent = self._pending.pop(resp.id, None)
        if t_sent is None:
            return                      # duplicate or post-close response
        status = resp.status or "rejected"
        self.stats[status] = self.stats.get(status, 0) + 1
        if status == "ok":
            self.latencies.append(t_recv - t_sent)
        # stitch: the echoed controller-side interval, then close the span
        self.recorder.span_remote(resp.id, resp.arrival, resp.completion)
        self.recorder.span_close(resp, t_recv)
        for r in self._responders:
            r(resp)

    def _on_close(self) -> None:
        if self.closed:
            return
        self.closed = True
        self.lost += len(self._pending)
        self._pending.clear()

    def close(self) -> None:
        """Hang up. The controller reclaims our in-flight bookkeeping on
        the channel-close callback (no leak, no send into a closed pipe)."""
        if not self.closed:
            self.channel.close()
            self._on_close()

    # --------------------------------------------------------- reporting
    @property
    def in_flight(self) -> int:
        return len(self._pending)

    def summary(self) -> dict:
        """Client-observed counters + latency percentiles (seconds)."""
        return {"sent": self.sent, "goodput": self.stats["ok"],
                "timeout": self.stats["timeout"],
                "rejected": self.stats["rejected"],
                "in_flight": self.in_flight, "lost": self.lost,
                "p50": quantile(self.latencies, 0.50),
                "p99": quantile(self.latencies, 0.99)}

    def report(self) -> dict:
        """Span-level breakdown: client-observed vs controller-observed
        latency and the per-request network overhead between them."""
        return client_breakdown(self.recorder.iter_spans())
