"""Workload generators: closed-loop clients, open-loop Poisson clients, and
an MAF-like trace synthesizer (Microsoft Azure Functions workload shapes:
sustained / bursty / periodic / cold — §6.5 of the paper).

Every generator drives an arbitrary `submit(Request)` callable, so the
same seeded workload runs against an in-process controller
(`Cluster.submit`), a loopback `RemoteClient.submit`, or a real TCP
client in the load-generator process (`python -m repro.runtime.loadgen`)
— `build_workload` is the one factory all three paths share."""
from __future__ import annotations

import math
import random
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.actions import Request
from repro.core.clock import EventLoop


class ClosedLoopClient:
    """`concurrency` outstanding requests; next sent upon each response."""

    def __init__(self, loop: EventLoop, submit: Callable[[Request], None],
                 model_id: str, slo: float, concurrency: int = 1,
                 start: float = 0.0, stop: Optional[float] = None):
        self.loop = loop
        self.submit = submit
        self.model_id = model_id
        self.slo = slo
        self.concurrency = concurrency
        self.stop = stop
        self.sent = 0
        for _ in range(concurrency):
            loop.schedule(start, self._send)

    def _send(self):
        now = self.loop.now()
        if self.stop is not None and now >= self.stop:
            return
        r = Request(model_id=self.model_id, arrival=now, slo=self.slo)
        self.sent += 1
        self.submit(r)

    def on_response(self, req: Request):
        if req.model_id == self.model_id:
            self.loop.schedule(self.loop.now(), self._send)


class OpenLoopClient:
    """Poisson arrivals at `rate` r/s until `stop`."""

    def __init__(self, loop: EventLoop, submit: Callable[[Request], None],
                 model_id: str, slo: float, rate: float, start: float = 0.0,
                 stop: float = 60.0, seed: int = 0):
        self.loop = loop
        self.submit = submit
        self.model_id = model_id
        self.slo = slo
        self.rate = rate
        self.stop = stop
        self.rng = random.Random(seed)
        self.sent = 0
        if rate > 0:
            loop.schedule(start + self.rng.expovariate(rate), self._send)

    def _send(self):
        now = self.loop.now()
        if now >= self.stop:
            return
        self.sent += 1
        self.submit(Request(model_id=self.model_id, arrival=now,
                            slo=self.slo))
        self.loop.schedule(now + self.rng.expovariate(self.rate), self._send)


class VariableRateClient:
    """Open-loop with a piecewise-constant rate function (trace replay)."""

    def __init__(self, loop: EventLoop, submit: Callable[[Request], None],
                 model_id: str, slo: float, rate_fn: Callable[[float], float],
                 start: float = 0.0, stop: float = 60.0, seed: int = 0,
                 max_rate: float = 1000.0):
        self.loop = loop
        self.submit = submit
        self.model_id = model_id
        self.slo = slo
        self.rate_fn = rate_fn
        self.stop = stop
        self.rng = random.Random(seed)
        self.max_rate = max_rate
        self.sent = 0
        loop.schedule(start, self._send)   # thinning sampler

    def _send(self):
        # Lewis thinning: sample at max_rate, accept with rate/max_rate
        now = self.loop.now()
        if now >= self.stop:
            return
        dt = self.rng.expovariate(self.max_rate)
        t = now + dt
        if t >= self.stop:
            return

        def fire():
            r = self.rate_fn(self.loop.now())
            if self.rng.random() < r / self.max_rate:
                self.sent += 1
                self.submit(Request(model_id=self.model_id,
                                    arrival=self.loop.now(), slo=self.slo))
            self._send()

        self.loop.schedule(t, fire)


# ------------------------------------------------------------- the factory

WORKLOAD_KINDS = ("open", "closed", "maf")


def build_workload(loop: EventLoop, submit: Callable[[Request], None],
                   model_ids: Sequence[str], *, kind: str = "open",
                   slo: float = 0.100, rate: float = 10.0,
                   concurrency: int = 4, start: float = 0.0,
                   duration: float = 60.0, seed: int = 0,
                   total_rate: Optional[float] = None,
                   max_rate: float = 1000.0) -> list:
    """Build the standard generator mix over any submit callable.

    kind "open": one Poisson OpenLoopClient per model at `rate` r/s;
    "closed": one ClosedLoopClient per model with `concurrency`
    outstanding; "maf": MAF-shaped VariableRateClients splitting
    `total_rate` (default `rate * len(model_ids)`) across models. `start`
    offsets every generator onto the caller's clock (a TCP loadgen joins
    at loop.now() > 0; rate functions are phase-shifted to match), and
    `seed` makes the whole mix reproducible.
    """
    stop = start + duration
    clients: list = []
    if kind == "open":
        for i, mid in enumerate(model_ids):
            clients.append(OpenLoopClient(loop, submit, mid, slo, rate=rate,
                                          start=start, stop=stop,
                                          seed=seed + i))
    elif kind == "closed":
        for i, mid in enumerate(model_ids):
            clients.append(ClosedLoopClient(loop, submit, mid, slo,
                                            concurrency=concurrency,
                                            start=start, stop=stop))
    elif kind == "maf":
        fns = maf_like_rates(len(model_ids),
                             total_rate if total_rate is not None
                             else rate * len(model_ids),
                             duration, seed=seed)
        for i, mid in enumerate(model_ids):
            fn = fns[f"m{i}"]
            clients.append(VariableRateClient(
                loop, submit, mid, slo,
                rate_fn=lambda t, fn=fn, s=start: fn(t - s),
                start=start, stop=stop, seed=seed + i, max_rate=max_rate))
    else:
        raise ValueError(f"unknown workload kind {kind!r}; "
                         f"choose from {WORKLOAD_KINDS}")
    return clients


# ----------------------------------------------------------- MAF-like trace

def maf_like_rates(n_models: int, total_rate: float, duration: float,
                   seed: int = 0) -> Dict[str, Callable[[float], float]]:
    """Synthesize per-model rate functions with MAF-like shape mix:
    ~10% sustained heavy (zipf-weighted), ~30% bursty, ~20% periodic
    (60 s / 900 s spikes), ~40% cold/rare."""
    rng = random.Random(seed)
    weights = [1.0 / (i + 1) ** 1.1 for i in range(n_models)]
    wsum = sum(weights)
    fns = {}
    for i in range(n_models):
        mid = f"m{i}"
        base = total_rate * weights[i] / wsum
        kind = rng.random()
        if kind < 0.10:
            def fn(t, b=base):
                return b * 3.0
        elif kind < 0.40:
            period = rng.uniform(5, 60)
            phase = rng.uniform(0, period)
            burst = rng.uniform(2, 12)

            def fn(t, b=base, p=period, ph=phase, k=burst):
                return b * (k if ((t + ph) % p) < p * 0.2 else 0.3)
        elif kind < 0.60:
            period = rng.choice([60.0, 900.0])
            phase = rng.uniform(0, period)

            def fn(t, b=base, p=period, ph=phase):
                return b * (10.0 if ((t + ph) % p) < 2.0 else 0.5)
        else:
            def fn(t, b=base):
                return b * 0.2
        fns[mid] = fn
    return fns
