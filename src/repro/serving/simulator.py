"""Cluster simulation harness: builds controller + workers + clients on a
virtual clock and replays paper-scale experiments in seconds.

Model profiles come from two sources:
  * the paper's own Table 1 (v100 measurements) for the faithful
    ResNet-family reproduction, and
  * roofline-derived TPU v5e profiles for the assigned LM architectures
    (benchmarks/roofline.py writes them from dry-run artifacts).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

from repro.core.actions import ActionType, Request
from repro.core.clock import EventLoop, VirtualClock
from repro.core.controller import Controller
from repro.core.scheduler import ClockworkScheduler
from repro.core.worker import ModelDef, SimBackend, Worker
from repro.telemetry.profile_store import ProfileStore
from repro.telemetry.recorder import Recorder

# --- paper Table 1 (v100, TVM 0.7): model -> (weights MB, B1,B2,B4,B8,B16 ms)
PAPER_TABLE1 = {
    "resnet50_v2": (102.2, 2.73, 4.05, 5.87, 9.93, 17.3),
    "resnet18_v2": (46.7, 1.32, 1.81, 2.48, 4.42, 7.12),
    "resnet101_v2": (178.1, 5.51, 8.05, 11.83, 18.14, 33.57),
    "densenet121": (31.8, 3.80, 4.52, 6.55, 10.22, 17.91),
    "googlenet": (26.5, 1.54, 1.94, 2.69, 4.19, 7.11),
    "inceptionv3": (95.3, 4.46, 6.85, 10.99, 16.45, 26.17),
    "mobile_pose_mobilenet1.0": (20.0, 0.99, 1.72, 2.99, 5.67, 10.78),
    "resnest50": (109.8, 6.96, 9.47, 14.27, 29.94, 56.02),
    "resnext50_32x4d": (100.0, 2.18, 3.23, 5.35, 9.21, 17.42),
    "winograd_resnet18_v2": (77.4, 0.95, 1.17, 1.71, 2.81, 5.09),
}
PAPER_PCIE_BW = 12.3e9   # ~102.2MB / 8.32ms, v100 PCIe3 measured in Table 1


def table1_modeldef(model_id: str, family: str = "resnet50_v2") -> ModelDef:
    mb, b1, b2, b4, b8, b16 = PAPER_TABLE1[family]
    lat = {("INFER", b): ms / 1e3
           for b, ms in zip((1, 2, 4, 8, 16), (b1, b2, b4, b8, b16))}
    return ModelDef(model_id=model_id, weights_bytes=int(mb * 1e6),
                    exec_latency=lat)


def seed_profiles(models: Dict[str, ModelDef],
                  host_to_dev_bw: float) -> dict:
    out = {}
    for mid, md in models.items():
        for (t, b), d in md.exec_latency.items():
            out[(t, mid, b)] = d
        out[("LOAD", mid, 1)] = 1e-3 + md.weights_bytes / host_to_dev_bw
    return out


def make_sim_worker(i: int, loop: EventLoop, models: Dict[str, ModelDef], *,
                    gpus_per_worker: int, device_memory: float,
                    host_to_dev_bw: float, noise: float, spike_prob: float,
                    spike_scale: float, seed: int) -> Worker:
    """One simulated worker, identically constructed whether it lives
    in-process or behind the distributed runtime's loopback transport
    (the decision-equivalence tests depend on both builders agreeing)."""
    backend = SimBackend(host_to_dev_bw=host_to_dev_bw, noise=noise,
                         spike_prob=spike_prob, spike_scale=spike_scale,
                         seed=seed + i)
    return Worker(f"w{i}", loop, backend, models, n_gpus=gpus_per_worker,
                  device_memory_bytes=device_memory)


def place_preload(controller, workers: List[Worker],
                  models: Dict[str, ModelDef],
                  preload: Optional[List[str]]) -> None:
    """Round-robin warm placement before time starts: weights land in the
    worker pagecaches AND the controller mirrors (which must already be
    registered)."""
    if not preload:
        return
    gpu_list = [(w, g) for w in workers for g in range(w.n_gpus)]
    for j, mid in enumerate(preload):
        w, g = gpu_list[j % len(gpu_list)]
        md = models[mid]
        pages = md.pages(w.pagecaches[g].page_bytes)
        if w.pagecaches[g].alloc(mid, pages):
            mirr = controller.workers[w.worker_id].gpus[g]
            mirr.pagecache.alloc(mid, pages)


@dataclasses.dataclass
class Cluster:
    loop: EventLoop
    controller: Controller
    workers: List[Worker]
    models: Dict[str, ModelDef]
    clients: list = dataclasses.field(default_factory=list)
    # set when the cluster runs over the distributed runtime (loopback
    # transport): holds the ControllerServer/WorkerHosts/links and a
    # graceful shutdown() that flushes daemon telemetry
    runtime: Optional[object] = None

    def submit(self, req: Request):
        self.controller.on_request(req)

    def shutdown(self):
        """Gracefully wind down distributed plumbing (no-op in-process)."""
        if self.runtime is not None:
            self.runtime.shutdown()

    def attach_clients(self, clients):
        self.clients.extend(clients)
        existing = self.controller.on_response
        # bind the responder methods once — at thousands of clients the
        # per-response hasattr sweep was a simulator hot path
        responders = [c.on_response for c in self.clients
                      if hasattr(c, "on_response")]

        def fan(req):
            if existing:
                existing(req)
            for r in responders:
                r(req)

        self.controller.on_response = fan

    def run(self, t_end: float):
        self.loop.run_until(t_end)
        return self.controller.summary()

    # --------------------------------------------------------- telemetry
    @property
    def recorder(self) -> Recorder:
        return self.controller.recorder

    def telemetry_report(self) -> dict:
        """Latency breakdown + prediction-error + control-plane report for
        this run (scheduler tick-latency gauges, event-loop throughput)."""
        rep = self.controller.telemetry_report()
        rep["event_loop"] = self.loop.stats()
        return rep

    def export_profile_store(self) -> ProfileStore:
        """Fold this run's telemetry into a fresh ProfileStore (the
        shutdown-time persistence hook). Recorder records only — the
        ActionProfiler's windows hold the same durations and would be
        double-counted."""
        store = ProfileStore()
        store.update_from_recorder(self.recorder)
        return store


def build_cluster(models: Dict[str, ModelDef], *, n_workers: int = 1,
                  gpus_per_worker: int = 1, scheduler=None,
                  device_memory: float = 32e9, host_to_dev_bw: float = 12.3e9,
                  noise: float = 0.0003, spike_prob: float = 0.0,
                  spike_scale: float = 5.0,
                  action_delay: float = 0.0005, seed: int = 0,
                  preload: Optional[List[str]] = None,
                  profile_store: Optional[ProfileStore] = None,
                  recorder: Optional[Recorder] = None,
                  transport: Optional[str] = None,
                  **transport_kw) -> Cluster:
    if transport is not None:
        # route controller<->worker traffic through the distributed
        # runtime's wire protocol instead of direct calls (DESIGN.md §5);
        # transport_kw: latency/jitter/drop/transport_seed/...
        if transport != "loopback":
            raise ValueError(f"unknown transport {transport!r}; "
                             "multi-process runs use repro.runtime directly")
        from repro.runtime.harness import build_loopback_cluster
        return build_loopback_cluster(
            models, n_workers=n_workers, gpus_per_worker=gpus_per_worker,
            scheduler=scheduler, device_memory=device_memory,
            host_to_dev_bw=host_to_dev_bw, noise=noise,
            spike_prob=spike_prob, spike_scale=spike_scale,
            action_delay=action_delay, seed=seed, preload=preload,
            profile_store=profile_store, recorder=recorder, **transport_kw)
    loop = EventLoop(VirtualClock())
    sched = scheduler if scheduler is not None else ClockworkScheduler()
    workers = []
    controller = Controller(loop, models, sched, action_delay=action_delay,
                            recorder=recorder)
    # persisted profiles win over the synthetic ground-truth-derived seeds
    profiles = profile_store.seed_dict() if profile_store is not None \
        else seed_profiles(models, host_to_dev_bw)
    for i in range(n_workers):
        w = make_sim_worker(i, loop, models,
                            gpus_per_worker=gpus_per_worker,
                            device_memory=device_memory,
                            host_to_dev_bw=host_to_dev_bw, noise=noise,
                            spike_prob=spike_prob,
                            spike_scale=spike_scale, seed=seed)
        workers.append(w)
        controller.add_worker(w, profiles if i == 0 else None)
    place_preload(controller, workers, models, preload)
    return Cluster(loop=loop, controller=controller, workers=workers,
                   models=models)


class TimeSeries:
    """Windowed goodput/latency sampler for figure benchmarks."""

    def __init__(self, cluster: Cluster, dt: float = 1.0):
        self.cluster = cluster
        self.dt = dt
        self.samples = []
        self._last_counts = dict(cluster.controller.stats)
        self._window_lat: List[float] = []
        base = cluster.controller.on_response

        def hook(req):
            if base:
                base(req)
            if req.status == "ok":
                self._window_lat.append(req.completion - req.arrival)

        cluster.controller.on_response = hook
        cluster.loop.schedule(dt, self._sample)

    def _sample(self):
        c = self.cluster.controller
        now = self.cluster.loop.now()
        cur = dict(c.stats)
        lat = sorted(self._window_lat)

        def pct(q):
            return lat[min(len(lat) - 1, int(q * len(lat)))] if lat else None

        self.samples.append({
            "t": now,
            "goodput_rs": (cur["goodput"]
                           - self._last_counts["goodput"]) / self.dt,
            "timeout_rs": (cur["timeout"]
                           - self._last_counts["timeout"]) / self.dt,
            "rejected_rs": (cur["rejected"]
                            - self._last_counts["rejected"]) / self.dt,
            "p50": pct(0.50), "p99": pct(0.99), "max": pct(1.0),
        })
        self._last_counts = cur
        self._window_lat = []
        self.cluster.loop.schedule(now + self.dt, self._sample)
