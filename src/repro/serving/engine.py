"""Real JAX execution backend for the Clockwork worker.

Mirrors the paper's model runtime (§5.1): each model is AOT-compiled per
batch-size bucket (default 1,2,4,8,16 like Clockwork's TVM kernels), weights
live in host memory and LOAD places them on device, EXEC runs exactly one
XLA program at a time. Execution times are measured and fed back to the
controller's profiler — on CPU they are noisier than a TPU (document the
Fig-2 analogue caveat), but the machinery is identical.

Profiles are persistent: `seed_from_store` / `seed_engines` load a
ProfileStore written by the offline profiler CLI
(`python -m repro.telemetry.profiler`), so repeat runs perform zero
warmup re-measurements (`warmup_count` stays 0).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.worker import ModelDef
from repro.models import params as pspec
from repro.models.resnet import resnet50_forward, resnet50_spec
from repro.telemetry.profile_store import ProfileStore


class JaxModel:
    """One served model: params + per-batch-bucket jit'd callables."""

    def __init__(self, model_id: str, forward: Callable, params,
                 make_input: Callable[[int], dict], weights_bytes: int,
                 batches: Tuple[int, ...] = (1, 2, 4, 8, 16)):
        self.model_id = model_id
        self.forward = forward
        self.host_params = jax.tree.map(np.asarray, params)
        self.device_params = None
        self.make_input = make_input
        self.weights_bytes = weights_bytes
        self.batches = tuple(sorted(batches))
        self._jitted = {b: jax.jit(forward) for b in self.batches}
        self._measured: Dict[Tuple[str, int], float] = {}
        self._load_s: Optional[float] = None
        self._fresh: set = set()     # keys measured in-process (not echoes)
        self.warmup_count = 0        # timed profiling measurements performed

    def load(self) -> float:
        t0 = time.perf_counter()
        self.device_params = jax.device_put(self.host_params)
        jax.block_until_ready(self.device_params)
        return time.perf_counter() - t0

    def unload(self):
        self.device_params = None

    def bucket(self, batch: int) -> int:
        for b in self.batches:
            if b >= batch:
                return b
        return self.batches[-1]

    def run(self, batch: int) -> float:
        b = self.bucket(batch)
        if self.device_params is None:
            self.load()
        x = self.make_input(b)
        t0 = time.perf_counter()
        out = self._jitted[b](self.device_params, x)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    def compile(self):
        """AOT-compile every batch bucket without recording timings —
        compilation is not warmup re-measurement (paper §5.1: kernels are
        compiled ahead of time; profiles come from the ProfileStore)."""
        if self.device_params is None:
            self.load()
        for b in self.batches:
            x = self.make_input(b)
            jax.block_until_ready(self._jitted[b](self.device_params, x))

    # ------------------------------------------------------ profiling
    def measure(self, reps: int = 3) -> Dict[Tuple[str, int], list]:
        """Timed sweep over batch buckets; returns raw durations per
        ("INFER", batch). The first rep per bucket (compile) is dropped."""
        if self.device_params is None:
            self.load()
        out = {}
        for b in self.batches:
            durs = [self.run(b) for _ in range(reps + 1)][1:]
            self.warmup_count += reps + 1
            out[("INFER", b)] = durs
        return out

    def measure_load(self, reps: int = 2) -> List[float]:
        """Timed host->device weight transfers (the LOAD profile)."""
        durs = []
        for _ in range(max(1, reps)):
            self.unload()
            durs.append(max(self.load(), 1e-5))
            self.warmup_count += 1
        self._load_s = float(np.median(durs))
        self._fresh.add(("LOAD", 1))
        return durs

    def warmup(self, reps: int = 3):
        for (t, b), durs in self.measure(reps=reps).items():
            self._measured[(t, b)] = float(np.median(durs))
            self._fresh.add((t, b))

    def apply_profile(self, entries: Dict[Tuple[str, int], float]):
        """Seed measurements from persisted profiles — {("INFER", batch)
        or ("LOAD", 1): seconds} — so no warmup re-measurement happens."""
        for (t, b), d in entries.items():
            if t == "LOAD":
                self._load_s = float(d)
            else:
                self._measured[(t, b)] = float(d)
            self._fresh.discard((t, b))

    def seed_from_store(self, store: ProfileStore) -> bool:
        """Seed from a ProfileStore; returns False (and seeds nothing) if
        any of this model's batch buckets is missing from the store."""
        entries = {}
        for b in self.batches:
            p = store.get("INFER", self.model_id, b)
            if p is None:
                return False
            entries[("INFER", b)] = p.estimate
        lp = store.get("LOAD", self.model_id, 1)
        if lp is not None:
            entries[("LOAD", 1)] = lp.estimate
        self.apply_profile(entries)
        return True

    def seed_profiles(self) -> dict:
        if not self._measured:
            self.warmup()
        out = {("INFER", self.model_id, b): d
               for (_, b), d in self._measured.items()}
        if self._load_s is None:
            self.measure_load(reps=1)
        out[("LOAD", self.model_id, 1)] = self._load_s
        return out

    def fresh_profiles(self) -> dict:
        """Like seed_profiles(), restricted to values measured in this
        process — store-seeded echoes are excluded, so folding these back
        into a ProfileStore can never recycle its own estimates."""
        return {(t, mid, b): d
                for (t, mid, b), d in self.seed_profiles().items()
                if (t, b) in self._fresh}

    def modeldef(self) -> ModelDef:
        if not self._measured:
            self.warmup()
        return ModelDef(model_id=self.model_id,
                        weights_bytes=self.weights_bytes,
                        exec_latency={("INFER", b): d for (_, b), d
                                      in self._measured.items()},
                        runner=self.run)


class JaxBackend:
    """Worker backend that actually executes (RealClock mode)."""

    realtime = True
    load_fixed = 1e-4

    def __init__(self, models: Dict[str, JaxModel]):
        self.models = models

    def load_duration(self, model: ModelDef) -> float:
        return max(self.models[model.model_id].load(), 1e-6)

    def exec_duration(self, model: ModelDef, action) -> float:
        return max(self.models[model.model_id].run(action.batch_size), 1e-6)


def seed_engines(engines: Dict[str, JaxModel],
                 store: Optional[ProfileStore] = None) -> dict:
    """Seed every engine's profiles — from `store` when it covers the
    engine's buckets (zero warmup re-measurement), measuring otherwise —
    and return the combined (type, model, batch) -> secs dict that
    `Controller.add_worker(profiles=...)` takes."""
    profiles = {}
    for e in engines.values():
        if store is not None:
            e.seed_from_store(store)
        profiles.update(e.seed_profiles())
    return profiles


def update_store(engines: Dict[str, JaxModel], store: ProfileStore,
                 controller=None) -> ProfileStore:
    """Shutdown path: fold measured engine profiles and (optionally) the
    controller's live telemetry back into the persistent store.

    Only values actually measured this run (fresh_profiles) are folded —
    a store-seeded engine's seed_profiles() merely echoes the store's own
    estimates, and folding those back would let stale values masquerade
    as fresh samples. Live telemetry is folded from the Recorder only:
    the ActionProfiler's windows hold the same durations and would
    double-count them.
    """
    for e in engines.values():
        for (t, mid, b), d in e.fresh_profiles().items():
            store.update(t, mid, b, [d])
    if controller is not None:
        store.update_from_recorder(controller.recorder)
    return store


def make_resnet_model(model_id: str, scale: int = 16, img: int = 64,
                      batches=(1, 2, 4, 8, 16), seed: int = 0) -> JaxModel:
    """Reduced ResNet-50 (the paper's evaluation model) runnable on CPU."""
    spec = resnet50_spec(num_classes=256, scale=scale)
    params = pspec.materialize(spec, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)

    def make_input(b):
        return jnp.asarray(rng.standard_normal((b, img, img, 3)),
                           jnp.float32)

    return JaxModel(model_id, resnet50_forward, params, make_input,
                    weights_bytes=pspec.param_bytes(spec), batches=batches)


def make_lm_decode_model(model_id: str, arch: str = "qwen2-0.5b",
                         batches=(1, 2, 4, 8), ctx: int = 128,
                         seed: int = 0) -> JaxModel:
    """Reduced LM whose INFER action is one DECODE step (continuous-batching
    unit) — the Clockwork-for-LLMs adaptation (DESIGN.md §2)."""
    from repro.configs import get_smoke_config
    from repro.models.registry import get_bundle
    cfg = get_smoke_config(arch)
    bundle = get_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))

    def forward(p, x):
        # one decode step against a ctx-sized cache (latency-equivalent to
        # steady-state decode; cache contents don't affect the compute cost)
        tokens, cur = x
        cache = bundle.init_cache(tokens.shape[0], ctx)
        logits, _ = bundle.decode(p, cache, tokens, cur)
        return logits

    def make_input(b):
        return (jnp.zeros((b, 1), jnp.int32),
                jnp.asarray(ctx // 2, jnp.int32))

    return JaxModel(model_id, forward, params, make_input,
                    weights_bytes=pspec.param_bytes(bundle.spec()),
                    batches=batches)
