"""Real JAX execution backend for the Clockwork worker.

Mirrors the paper's model runtime (§5.1): each model is AOT-compiled per
batch-size bucket (default 1,2,4,8,16 like Clockwork's TVM kernels), weights
live in host memory and LOAD places them on device, EXEC runs exactly one
XLA program at a time. Execution times are measured and fed back to the
controller's profiler — on CPU they are noisier than a TPU (document the
Fig-2 analogue caveat), but the machinery is identical.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.worker import ModelDef
from repro.models import params as pspec
from repro.models.resnet import resnet50_forward, resnet50_spec


class JaxModel:
    """One served model: params + per-batch-bucket jit'd callables."""

    def __init__(self, model_id: str, forward: Callable, params,
                 make_input: Callable[[int], dict], weights_bytes: int,
                 batches: Tuple[int, ...] = (1, 2, 4, 8, 16)):
        self.model_id = model_id
        self.forward = forward
        self.host_params = jax.tree.map(np.asarray, params)
        self.device_params = None
        self.make_input = make_input
        self.weights_bytes = weights_bytes
        self.batches = tuple(sorted(batches))
        self._jitted = {b: jax.jit(forward) for b in self.batches}
        self._measured: Dict[Tuple[str, int], float] = {}

    def load(self) -> float:
        t0 = time.perf_counter()
        self.device_params = jax.device_put(self.host_params)
        jax.block_until_ready(self.device_params)
        return time.perf_counter() - t0

    def unload(self):
        self.device_params = None

    def bucket(self, batch: int) -> int:
        for b in self.batches:
            if b >= batch:
                return b
        return self.batches[-1]

    def run(self, batch: int) -> float:
        b = self.bucket(batch)
        if self.device_params is None:
            self.load()
        x = self.make_input(b)
        t0 = time.perf_counter()
        out = self._jitted[b](self.device_params, x)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    def warmup(self, reps: int = 3):
        if self.device_params is None:
            self.load()
        for b in self.batches:
            durs = [self.run(b) for _ in range(reps + 1)][1:]  # drop compile
            self._measured[("INFER", b)] = float(np.median(durs))

    def seed_profiles(self) -> dict:
        if not self._measured:
            self.warmup()
        out = {("INFER", self.model_id, b): d
               for (_, b), d in self._measured.items()}
        out[("LOAD", self.model_id, 1)] = max(self.load(), 1e-5)
        return out

    def modeldef(self) -> ModelDef:
        if not self._measured:
            self.warmup()
        return ModelDef(model_id=self.model_id,
                        weights_bytes=self.weights_bytes,
                        exec_latency={("INFER", b): d for (_, b), d
                                      in self._measured.items()},
                        runner=self.run)


class JaxBackend:
    """Worker backend that actually executes (RealClock mode)."""

    realtime = True
    load_fixed = 1e-4

    def __init__(self, models: Dict[str, JaxModel]):
        self.models = models

    def load_duration(self, model: ModelDef) -> float:
        return max(self.models[model.model_id].load(), 1e-6)

    def exec_duration(self, model: ModelDef, action) -> float:
        return max(self.models[model.model_id].run(action.batch_size), 1e-6)


def make_resnet_model(model_id: str, scale: int = 16, img: int = 64,
                      batches=(1, 2, 4, 8, 16), seed: int = 0) -> JaxModel:
    """Reduced ResNet-50 (the paper's evaluation model) runnable on CPU."""
    spec = resnet50_spec(num_classes=256, scale=scale)
    params = pspec.materialize(spec, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)

    def make_input(b):
        return jnp.asarray(rng.standard_normal((b, img, img, 3)),
                           jnp.float32)

    return JaxModel(model_id, resnet50_forward, params, make_input,
                    weights_bytes=pspec.param_bytes(spec), batches=batches)


def make_lm_decode_model(model_id: str, arch: str = "qwen2-0.5b",
                         batches=(1, 2, 4, 8), ctx: int = 128,
                         seed: int = 0) -> JaxModel:
    """Reduced LM whose INFER action is one DECODE step (continuous-batching
    unit) — the Clockwork-for-LLMs adaptation (DESIGN.md §2)."""
    from repro.configs import get_smoke_config
    from repro.models.registry import get_bundle
    cfg = get_smoke_config(arch)
    bundle = get_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))

    def forward(p, x):
        # one decode step against a ctx-sized cache (latency-equivalent to
        # steady-state decode; cache contents don't affect the compute cost)
        tokens, cur = x
        cache = bundle.init_cache(tokens.shape[0], ctx)
        logits, _ = bundle.decode(p, cache, tokens, cur)
        return logits

    def make_input(b):
        return (jnp.zeros((b, 1), jnp.int32),
                jnp.asarray(ctx // 2, jnp.int32))

    return JaxModel(model_id, forward, params, make_input,
                    weights_bytes=pspec.param_bytes(bundle.spec()),
                    batches=batches)
