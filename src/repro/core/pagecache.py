"""Paged device-memory accounting (§5.2).

Device HBM is pre-divided into fixed pages; models occupy an integral number
of pages. Paging "simplifies choice": the controller mirrors each worker's
memory exactly by tracking a single integer (free pages) plus the resident
set. We extend the idea to KV-cache pages for LM serving (DESIGN.md §2).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

PAGE_BYTES = 16 * 1024 * 1024      # 16 MB, as in the paper


class PageCache:
    def __init__(self, total_bytes: int, page_bytes: int = PAGE_BYTES):
        self.page_bytes = page_bytes
        self.total_pages = int(total_bytes // page_bytes)
        self.free_pages = self.total_pages
        self.resident: Dict[str, int] = {}       # model_id -> pages held
        # LRU order as an insertion-ordered dict used as a set: O(1)
        # touch/free instead of the O(n) list.remove on every EXEC
        self._lru: Dict[str, None] = {}           # least-recent first
        # optional hook fired when the resident *set* changes (model, added);
        # the controller uses it to keep a cluster-wide residency index in
        # sync with its mirrors, whoever mutates them
        self.on_resident_change = None

    @staticmethod
    def pages_for(nbytes: int, page_bytes: int = PAGE_BYTES) -> int:
        return max(1, math.ceil(nbytes / page_bytes))

    def contains(self, model_id: str) -> bool:
        return model_id in self.resident

    def can_alloc(self, pages: int) -> bool:
        return self.free_pages >= pages

    def alloc(self, model_id: str, pages: int) -> bool:
        if model_id in self.resident:
            self.touch(model_id)
            return True
        if self.free_pages < pages:
            return False
        self.free_pages -= pages
        self.resident[model_id] = pages
        self._lru[model_id] = None
        if self.on_resident_change is not None:
            self.on_resident_change(model_id, True)
        return True

    def free(self, model_id: str) -> int:
        pages = self.resident.pop(model_id, 0)
        self.free_pages += pages
        self._lru.pop(model_id, None)
        if pages and self.on_resident_change is not None:
            self.on_resident_change(model_id, False)
        return pages

    def touch(self, model_id: str):
        if model_id in self._lru:
            del self._lru[model_id]
            self._lru[model_id] = None

    def lru_candidate(self, exclude=()) -> Optional[str]:
        for m in self._lru:
            if m not in exclude:
                return m
        return None

    def used_pages(self) -> int:
        return self.total_pages - self.free_pages

    def utilization(self) -> float:
        return self.used_pages() / max(self.total_pages, 1)
