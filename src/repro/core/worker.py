"""Predictable worker (§4.4, §5.2).

One executor per (GPU/chip-slice, resource class): EXEC runs one inference at
a time (on TPU this is native — an XLA program owns the chip); LOAD owns the
host->HBM DMA path. Executors dequeue chronologically by `earliest`, wait
until `earliest`, and reject actions whose `latest` has passed — workers never
queue best-effort work, which is what stops stragglers from cascading.

Backends supply durations:
  * SimBackend — profile tables + configurable noise/spikes (C3), virtual time
  * callable backends (serving/engine.py) — actually execute JAX programs and
    return measured wall time (RealClock)
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
from typing import Callable, Dict, Optional, Tuple

from repro.core.actions import (EXEC_TYPES, Action, ActionType, Result,
                                ResultStatus)
from repro.core.clock import EventLoop
from repro.core.pagecache import PAGE_BYTES, PageCache


@dataclasses.dataclass
class ModelDef:
    """Ground-truth model properties (the controller sees only telemetry)."""
    model_id: str
    weights_bytes: int
    exec_latency: Dict[Tuple[str, int], float]   # (action_type, batch) -> s
    input_bytes: int = 602_112                   # paper Table 1 default
    output_bytes: int = 4_096
    runner: Optional[Callable] = None            # real execution hook

    def pages(self, page_bytes: int = PAGE_BYTES) -> int:
        return PageCache.pages_for(self.weights_bytes, page_bytes)


class SimBackend:
    """Deterministic-latency execution with controllable jitter.

    noise: multiplicative gaussian sigma (DNN inference ~0.03% in the paper);
    spike_prob/spike_scale: rare external-factor delays (C3).
    """

    realtime = False

    def __init__(self, host_to_dev_bw: float = 25e9, load_fixed: float = 1e-3,
                 noise: float = 0.0003, spike_prob: float = 0.0,
                 spike_scale: float = 5.0, seed: int = 0):
        self.host_to_dev_bw = host_to_dev_bw
        self.load_fixed = load_fixed
        self.noise = noise
        self.spike_prob = spike_prob
        self.spike_scale = spike_scale
        self.rng = random.Random(seed)

    def _jitter(self, d: float) -> float:
        if self.noise:
            d *= max(0.0, self.rng.gauss(1.0, self.noise))
        if self.spike_prob and self.rng.random() < self.spike_prob:
            d *= self.spike_scale
        return d

    def load_duration(self, model: ModelDef) -> float:
        return self._jitter(self.load_fixed
                            + model.weights_bytes / self.host_to_dev_bw)

    def exec_duration(self, model: ModelDef, action: Action) -> float:
        key = (action.type.value, action.batch_size)
        if key not in model.exec_latency:
            # interpolate: nearest known batch scaled linearly
            known = sorted(b for (t, b) in model.exec_latency
                           if t == action.type.value)
            if not known:
                raise KeyError(key)
            b0 = min(known, key=lambda b: abs(b - action.batch_size))
            base = model.exec_latency[(action.type.value, b0)]
            d = base * action.batch_size / b0
        else:
            d = model.exec_latency[key]
        return self._jitter(d)


class Executor:
    """Serial action executor with [earliest, latest] window enforcement."""

    def __init__(self, worker: "Worker", gpu_id: int, name: str):
        self.worker = worker
        self.gpu_id = gpu_id
        self.name = name
        self.q = []                      # heap: (earliest, seq, action)
        self._seq = itertools.count()
        self.busy = False
        self.busy_until = 0.0
        self.total_busy = 0.0            # utilization telemetry

    def submit(self, action: Action):
        heapq.heappush(self.q, (action.earliest, next(self._seq), action))
        self._poll()

    def _poll(self):
        loop = self.worker.loop
        if self.busy or not self.worker.alive:
            return
        while self.q:
            earliest, _, action = self.q[0]
            now = loop.now()
            if now < earliest - 1e-9:
                wake = earliest
                heapq.heappop(self.q)
                heapq.heappush(self.q, (earliest, next(self._seq), action))
                loop.schedule(wake, self._poll)
                return
            heapq.heappop(self.q)
            if now > action.latest + 1e-9:
                self.worker.emit_result(action, ResultStatus.REJECTED_LATE,
                                        now, now, 0.0)
                continue
            status, duration = self.worker.perform(action)
            if status is not ResultStatus.SUCCESS:
                self.worker.emit_result(action, status, now, now, 0.0)
                continue
            self.busy = True
            end = loop.now() + (0.0 if self.worker.backend.realtime
                                else duration)
            self.busy_until = end
            self.total_busy += duration

            def _done(a=action, t0=now, d=duration):
                self.busy = False
                self.worker.finish(a)
                self.worker.emit_result(a, ResultStatus.SUCCESS, t0,
                                        self.worker.loop.now()
                                        if self.worker.backend.realtime
                                        else t0 + d, d)
                self._poll()

            loop.schedule(end, _done)
            return


class Worker:
    """One worker process managing `n_gpus` accelerator slices."""

    def __init__(self, worker_id: str, loop: EventLoop,
                 backend: SimBackend, models: Dict[str, ModelDef],
                 n_gpus: int = 1, device_memory_bytes: float = 32e9,
                 reserved_bytes: float = 1e9,
                 result_delay: float = 0.0005):
        self.worker_id = worker_id
        self.loop = loop
        self.backend = backend
        self.models = models
        self.alive = True
        self.result_delay = result_delay
        self.on_result: Optional[Callable[[Result], None]] = None
        self.pagecaches = [PageCache(int(device_memory_bytes
                                         - reserved_bytes))
                           for _ in range(n_gpus)]
        self.execs: Dict[Tuple[int, str], Executor] = {}
        for g in range(n_gpus):
            self.execs[(g, "EXEC")] = Executor(self, g, "EXEC")
            self.execs[(g, "LOAD")] = Executor(self, g, "LOAD")
        self.n_gpus = n_gpus

    # -------------------------------------------------- controller-facing
    def receive(self, action: Action):
        if not self.alive:
            return
        action.received_at = self.loop.now()
        lane = "LOAD" if action.type in (ActionType.LOAD,
                                         ActionType.UNLOAD) else "EXEC"
        self.execs[(action.gpu_id, lane)].submit(action)

    def ping(self, reply: Callable[[], None]):
        if self.alive:
            self.loop.schedule_in(self.result_delay, reply)

    def fail(self):
        """Crash: drop all queued work, stop emitting results."""
        self.alive = False

    # -------------------------------------------------- execution
    def perform(self, action: Action):
        """Returns (status, duration). Called at action start time."""
        pc = self.pagecaches[action.gpu_id]
        model = self.models.get(action.model_id)
        if model is None:
            return ResultStatus.ERROR_NOT_LOADED, 0.0
        if action.type == ActionType.LOAD:
            if pc.contains(action.model_id):
                return ResultStatus.SUCCESS, 1e-5
            if not pc.alloc(action.model_id, model.pages(pc.page_bytes)):
                return ResultStatus.ERROR_NO_PAGES, 0.0
            return ResultStatus.SUCCESS, self.backend.load_duration(model)
        if action.type == ActionType.UNLOAD:
            pc.free(action.model_id)
            return ResultStatus.SUCCESS, 1e-5
        # EXEC family
        if not pc.contains(action.model_id):
            return ResultStatus.ERROR_NOT_LOADED, 0.0
        pc.touch(action.model_id)
        return ResultStatus.SUCCESS, self.backend.exec_duration(model, action)

    def finish(self, action: Action):
        pass  # hook (real backends release IO buffers here)

    def emit_result(self, action: Action, status: ResultStatus,
                    t_start: float, t_end: float, duration: float):
        if not self.alive or self.on_result is None:
            return
        r = Result(action_id=action.id, action_type=action.type,
                   model_id=action.model_id, worker_id=self.worker_id,
                   gpu_id=action.gpu_id, status=status, t_start=t_start,
                   t_end=t_end, duration=duration,
                   batch_size=action.batch_size,
                   request_ids=action.request_ids,
                   t_received=action.received_at)
        self.loop.schedule_in(self.result_delay, lambda: self.on_result(r))

    # -------------------------------------------------- runtime descriptor
    def spec(self) -> dict:
        """Wire-serializable descriptor of this worker (memory geometry) —
        the payload a WorkerDaemon sends in its HELLO so the controller can
        build an exact PageCache mirror without sharing the process."""
        return {"worker_id": self.worker_id,
                "gpus": [{"total_pages": pc.total_pages,
                          "page_bytes": pc.page_bytes}
                         for pc in self.pagecaches]}

    # -------------------------------------------------- telemetry
    def utilization(self, horizon: float) -> Dict[str, float]:
        out = {}
        for (g, name), ex in self.execs.items():
            out[f"gpu{g}/{name}"] = ex.total_busy / max(horizon, 1e-9)
        return out
