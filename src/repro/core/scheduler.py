"""The Clockwork scheduler (Appendix B) — incremental implementation.

Strategies: for each model with pending requests and each supported batch
size b, a strategy's *required start time* is

    min(deadline of the b oldest requests) - est_exec(model, b)

Larger batches have earlier required starts and are preferred. Each EXEC
executor is kept busy `schedule_ahead` (5 ms) into the future by scanning the
strategy order: skip models not loaded on that executor, batch sizes with
insufficient requests, batch sizes that are too small when a larger one is
eligible, and strategies that cannot complete in time.

LOAD selection uses the demand/allocation estimates: per model demand d_m
(outstanding exec-seconds), allocations a_{m,g} inversely proportional to GPU
load, load priority p_m = d_m - sum_g a_{m,g} * capacity_g / l_g. The highest
positive-priority non-resident model is loaded; LRU victims are UNLOADed when
pages are needed.

Scalability (DESIGN.md §4): the original implementation rebuilt and re-sorted
the full (required_start, model, batch) strategy list after *every* scheduled
action, making one tick O(models × batches × actions) — at paper scale
(thousands of models) the control plane, not the GPUs, became the binding
constraint. This implementation produces bit-identical decisions with
incremental data structures:

  * one globally *maintained* sorted strategy list; a model's ≤|batches|
    entries are spliced out and re-inserted (bisect) only when that model is
    dirtied — by a queue change or a new profile measurement — so scheduling
    one action costs O(log n) maintenance instead of an O(n·b log n·b)
    rebuild;
  * per-model prefix-min deadline views, so feasibility checks and batch
    deadlines are O(1) lookups instead of re-deriving min(deadline) per
    candidate;
  * profiler estimates memoized per (model, batch) until that model's
    profile actually changes (they cannot change mid-tick);
  * `_drop_hopeless` keeps a per-queue min-deadline lower bound and skips
    queues that provably contain nothing to drop; when it must scan, it is
    a single rotate pass (the original restarted the scan after every
    deletion — O(n²) per queue);
  * `_demands` is O(1) per model (the original summed a constant in an
    O(n) loop) and the LOAD allocation loop computes the same values
    without building the per-model inverse/allocation dicts.

Decision behavior is bit-identical to the frozen pre-optimization copy in
`repro.core.scheduler_reference` — enforced by the seeded decision-
equivalence tests in tests/test_scheduler_perf.py. Per-tick wall latency is
recorded into the controller's Recorder as the `scheduler.tick_latency_s`
gauge (see telemetry reports / BENCH_scheduler.json).
"""
from __future__ import annotations

import bisect
import collections
import itertools
import time
from typing import Deque, Dict, List, Optional

from repro.core.actions import Action, ActionType, Request, Result

DEFAULT_BATCHES = (1, 2, 4, 8, 16)

TICK_LATENCY_GAUGE = "scheduler.tick_latency_s"

_INF = float("inf")


class ClockworkScheduler:
    def __init__(self, *, schedule_ahead: float = 0.005,
                 batch_sizes=DEFAULT_BATCHES,
                 action_type: ActionType = ActionType.INFER,
                 load_window: float = 0.250,
                 max_loads_in_flight_per_gpu: int = 2):
        self.schedule_ahead = schedule_ahead
        self.batch_sizes = tuple(sorted(batch_sizes))
        self.action_type = action_type
        self._atype_val = action_type.value   # enum .value is a slow descriptor
        self.load_window = load_window
        self.max_loads = max_loads_in_flight_per_gpu
        self.c: Optional["Controller"] = None
        self.queues: Dict[str, Deque[Request]] = collections.defaultdict(
            collections.deque)
        self._in_tick = False
        # ---- incremental strategy state -------------------------------
        self._active: set = set()        # models with nonempty queues
        self._dirty: set = set()         # models whose entries are stale
        self._sorted: List[tuple] = []   # global sorted (req_start, mid, b)
        self._entries: Dict[str, list] = {}   # mid -> its tuples in _sorted
        self._pmins: Dict[str, list] = {}     # mid -> prefix-min deadlines
        self._est_mem: Dict[str, dict] = {}   # mid -> {batch: estimate}
        self._qmin: Dict[str, float] = {}     # mid -> queue min-deadline (lb)
        self._dval: Dict[str, float] = {}     # mid -> len(q)·est1 (demand)
        self._hopeless_at: Dict[str, float] = {}  # mid -> qmin - est1
        self._wcache: Dict[str, tuple] = {}   # mid -> (res_ver, where tuple)
        # multiset of queued request ids: the failure/requeue race can put
        # the SAME request in a queue twice (both implementations do), and
        # the dead-request hint below must see the copy that remains queued
        self._queued_ids: Dict[int, int] = {}
        self._scan_force: set = set()    # models that may hold dead requests
        self._qpos: Dict[str, int] = {}  # mid -> queue-dict insertion rank
        self._qpos_seq = itertools.count()
        # (qpos, mid) for active models, kept sorted; deactivated models are
        # removed lazily (consumers skip empty queues), so activation is one
        # bisect.insort instead of a per-tick sort of the active set
        self._order: List[tuple] = []
        self._order_set: set = set()     # mids currently in _order
        self.last_tick_s = 0.0           # wall-clock latency of the last tick

    # ---------------------------------------------------------- interface
    def attach(self, controller):
        self.c = controller

    def on_topology_change(self):
        # workers added/removed or profiles re-seeded: cached estimates and
        # everything derived from them may all be stale
        self._est_mem.clear()
        self._dval.clear()
        self._hopeless_at.clear()
        self._dirty.update(self._active)

    def _admit(self, req: Request):
        mid = req.model_id
        pos = self._qpos.get(mid)
        if pos is None:
            pos = self._qpos[mid] = next(self._qpos_seq)
        self._active.add(mid)
        self._dirty.add(mid)
        self._dval.pop(mid, None)
        q_ids = self._queued_ids
        q_ids[req.id] = q_ids.get(req.id, 0) + 1
        if mid not in self._order_set:
            self._order_set.add(mid)
            bisect.insort(self._order, (pos, mid))
        cur = self._qmin.get(mid)
        if cur is None or req.deadline < cur:
            # unconditionally ensure the entry exists — an infinite-SLO
            # request must still establish qmin (=inf) for _drop_hopeless
            self._qmin[mid] = req.deadline
            self._hopeless_at.pop(mid, None)

    def on_request(self, req: Request):
        self.queues[req.model_id].append(req)
        self._admit(req)

    def requeue(self, req: Request):
        if req.status is not None:
            return
        self.queues[req.model_id].appendleft(req)
        self._admit(req)

    def on_result(self, result: Result):
        # a result updates this model's profiler window, staling the
        # estimates baked into its strategy entries and derived caches
        mid = result.model_id
        self._est_mem.pop(mid, None)
        self._dval.pop(mid, None)
        self._hopeless_at.pop(mid, None)
        self._dirty.add(mid)
        # worker-failure requeue race: a result can complete a request that
        # was requeued and is *still in the queue* — only a scan removes it,
        # so flag the model for a forced scan on the next tick
        queued = self._queued_ids
        reqs = self.c.requests
        for rid in result.request_ids:
            if rid in queued:
                req = reqs.get(rid)
                if req is not None and req.status is not None:
                    self._scan_force.add(req.model_id)
                    break

    def _unqueue_id(self, rid: int):
        n = self._queued_ids.get(rid, 0)
        if n <= 1:
            self._queued_ids.pop(rid, None)
        else:
            self._queued_ids[rid] = n - 1

    def has_pending(self) -> bool:
        """O(1) pending-work probe for the controller's ticker."""
        return bool(self._active)

    # ---------------------------------------------------------- estimates
    def _est(self, model_id: str, b: int) -> Optional[float]:
        return self.c.profiler.estimate(self._atype_val, model_id, b)

    def _est_or_scale(self, model_id: str, b: int) -> float:
        # memoized until this model's profile changes (on_result/topology)
        mem = self._est_mem.get(model_id)
        if mem is None:
            mem = self._est_mem[model_id] = {}
        e = mem.get(b)
        if e is None:
            e = self._est(model_id, b)
            if e is None:
                e = b * self.c.profiler.estimate_or(
                    self._atype_val, model_id, 1, 0.005)
            mem[b] = e
        return e

    def _load_est(self, model_id: str) -> float:
        e = self.c.profiler.estimate("LOAD", model_id, 1)
        if e is not None:
            return e
        mdl = self.c.models[model_id]
        return 1e-3 + mdl.weights_bytes / 25e9

    # ---------------------------------------------------------- main loop
    def tick(self):
        if self.c is None or self._in_tick:
            return
        self._in_tick = True
        t0 = time.perf_counter()
        now = self.c.loop.now()
        try:
            # lazily compact the active-order list once stale (deactivated)
            # entries dominate it
            if len(self._order) > 16 and len(self._order) > 2 * len(self._active):
                self._order = [e for e in self._order if self.queues[e[1]]]
                self._order_set = {mid for _, mid in self._order}
            self._drop_hopeless(now)
            self._schedule_exec(now)
            self._schedule_loads(now)
        finally:
            self._in_tick = False
            self.last_tick_s = time.perf_counter() - t0
            rec = getattr(self.c, "recorder", None)
            if rec is not None:
                rec.record_gauge(TICK_LATENCY_GAUGE, now, self.last_tick_s)

    # Drop requests that can no longer meet their SLO anywhere (§4.1: cancel
    # before fruitless work). A queue is scanned only if its min-deadline
    # lower bound says something may be hopeless (the bound goes stale only
    # downward, so skipping is always sound) or a result hinted that a dead
    # request may still be queued; the scan itself is a single rotate pass.
    def _drop_hopeless(self, now: float):
        queues = self.queues
        qmin = self._qmin
        hmap = self._hopeless_at
        scan_force = self._scan_force
        for _, mid in self._order:
            h = hmap.get(mid)
            if h is None:
                q = queues[mid]
                if not q:
                    continue
                est1 = self._est_or_scale(mid, 1)
                h = hmap[mid] = qmin[mid] - est1
            if h >= now and mid not in scan_force:
                continue
            q = queues[mid]
            if not q:
                continue
            est1 = self._est_or_scale(mid, 1)
            scan_force.discard(mid)
            changed = False
            new_min = _INF
            kept = []
            # survivors go to a side list, not back onto the deque: a
            # reject() callback may synchronously submit new requests for
            # this model, and those must stay behind the survivors
            for _ in range(len(q)):
                r = q.popleft()
                if r.status is not None:
                    self._unqueue_id(r.id)
                    changed = True
                    continue
                if r.deadline - est1 < now:
                    self._unqueue_id(r.id)
                    self.c.reject(r)
                    changed = True
                    continue
                if r.deadline < new_min:
                    new_min = r.deadline
                kept.append(r)
            for r in q:
                # whatever remains was submitted mid-scan by a reject()
                # callback — fold it into the fresh minimum so the bound is
                # exact, not merely a (degrading) lower bound
                if r.deadline < new_min:
                    new_min = r.deadline
            if kept:
                q.extendleft(reversed(kept))
            if q:
                qmin[mid] = new_min
                hmap[mid] = new_min - est1
            else:
                qmin.pop(mid, None)
                hmap.pop(mid, None)
                self._active.discard(mid)
            if changed:
                self._dirty.add(mid)
                self._dval.pop(mid, None)

    # ------------------------------------------------- strategy maintenance
    def _flush_dirty(self):
        """Splice each dirty model's entries out of the global sorted list
        and re-insert its fresh ones — O(b log n) per dirty model."""
        if not self._dirty:
            return
        lst = self._sorted
        for mid in self._dirty:
            for t in self._entries.get(mid, ()):
                i = bisect.bisect_left(lst, t)
                del lst[i]          # exact tuple: (req_start, mid, b) unique
            q = self.queues.get(mid)
            if not q:
                self._entries.pop(mid, None)
                self._pmins.pop(mid, None)
                continue
            n = len(q)
            pmins: List[float] = []
            cur = _INF
            for i, r in enumerate(q):
                if i >= self.batch_sizes[-1]:
                    break
                d = r.deadline
                if d < cur:
                    cur = d
                pmins.append(cur)
            smallest = self.batch_sizes[0]
            entries = []
            for b in self.batch_sizes:
                if b > n and b != smallest:
                    continue
                eff = b if b < n else n
                t = (pmins[eff - 1] - self._est_or_scale(mid, b), mid, b)
                entries.append(t)
                bisect.insort(lst, t)
            self._entries[mid] = entries
            self._pmins[mid] = pmins
        self._dirty.clear()

    # ---------------------------------------------------------------- EXEC
    def _schedule_exec(self, now: float):
        self._flush_dirty()
        if not self._sorted:
            return
        horizon = now + self.schedule_ahead
        for wid, m in self.c.workers.items():
            for gid in m.gpu_ids():
                g = m.gpus[gid]
                while g.exec_free_at < horizon:
                    picked = self._pick_strategy(now, g)
                    if picked is None:
                        break
                    _, mid, b = picked
                    q = self.queues[mid]
                    take = min(b, len(q))
                    reqs = [q.popleft() for _ in range(take)]
                    for r in reqs:
                        self._unqueue_id(r.id)
                    exec_t = self._est_or_scale(mid, take)
                    dl = min(r.deadline for r in reqs)
                    a = Action(type=self.action_type, model_id=mid,
                               worker_id=wid, gpu_id=gid,
                               earliest=now, latest=max(now, dl - exec_t),
                               expected_duration=exec_t, batch_size=take,
                               request_ids=tuple(r.id for r in reqs))
                    self._dirty.add(mid)
                    self._dval.pop(mid, None)
                    if not q:
                        self._active.discard(mid)
                        self._qmin.pop(mid, None)
                        self._hopeless_at.pop(mid, None)
                    self.c.send_action(a)
                    self._flush_dirty()
                    if not self._sorted:
                        return

    def _pick_strategy(self, now: float, g) -> Optional[tuple]:
        avail = now if now > g.exec_free_at else g.exec_free_at
        contains = g.pagecache.resident.__contains__
        loading = g.loading
        queues = self.queues
        pmins = self._pmins
        smallest = self.batch_sizes[0]
        seen_models = None
        for e in self._sorted:
            mid = e[1]
            if not contains(mid) or mid in loading:
                continue  # not resident on this executor's GPU
            if seen_models is not None and mid in seen_models:
                continue  # a larger batch for this model already failed
            b = e[2]
            n = len(queues[mid])
            if b > n and b != smallest:
                continue
            eff = b if b < n else n
            exec_t = self._est_or_scale(mid, eff)
            if avail + exec_t > pmins[mid][eff - 1]:
                # cannot finish in time on this executor
                if seen_models is None:
                    seen_models = {mid}
                else:
                    seen_models.add(mid)
                continue
            return e
        return None

    # ---------------------------------------------------------- LOAD/UNLOAD
    def _demands(self) -> Dict[str, float]:
        # test/introspection view; _schedule_loads fuses the same values
        # into its allocation pass without materializing this dict
        d = {}
        for _, mid in self._order:
            if self.queues[mid]:
                d[mid] = self._demand(mid)
        return d

    def _demand(self, mid: str) -> float:
        dm = self._dval.get(mid)
        if dm is None:
            dm = self._dval[mid] = \
                len(self.queues[mid]) * self._est_or_scale(mid, 1)
        return dm

    def _where_of(self, mid: str) -> tuple:
        """GPU keys holding `mid`, in worker-registration order — cached
        until the controller's residency version for the model changes."""
        c = self.c
        ver = c._res_ver.get(mid, 0)
        hit = self._wcache.get(mid)
        if hit is not None and hit[0] == ver:
            return hit[1]
        s = c._residency.get(mid)
        if not s:
            w = ()
        elif len(s) == 1:
            w = tuple(s)
        else:
            w = tuple(sorted(s, key=c._gpu_ord.__getitem__))
        self._wcache[mid] = (ver, w)
        return w

    def _schedule_loads(self, now: float):
        c = self.c
        workers = c.workers
        if not workers:
            return
        # a GPU at its in-flight LOAD cap can't accept work, so if every
        # GPU is saturated the whole allocation pass can have no effect —
        # skip it (LOAD completions only land between ticks)
        max_loads = self.max_loads
        gpus = []
        for wid, m in workers.items():
            for gid in m.gpu_ids():
                g = m.gpus[gid]
                if len(g.loading) < max_loads:
                    gpus.append((wid, gid, g))
        if not gpus:
            return
        queues = self.queues
        where_of = self._where_of
        wcache = self._wcache
        res_ver = c._res_ver
        # Demand d_m = len(q)·est1 per pending model (memoized until the
        # queue or profile changes), in queue-dict insertion order so every
        # FP accumulation below matches the reference implementation.
        # GPU loads l_g: demand allocated to each gpu — a model's demand
        # splits evenly over the GPUs holding it (one share value, no
        # per-key inverse/allocation dicts), and the GPUs holding it come
        # from the controller's residency index, not a scan over every GPU.
        mids: list = []
        dms: list = []
        wlist: list = []
        loads: Dict[tuple, float] = {}
        for _, mid in self._order:
            if not queues[mid]:
                continue
            dm = self._demand(mid)
            # inline fast path of _where_of (this loop visits every pending
            # model every tick); _where_of remains the only writer/slow path
            hit = wcache.get(mid)
            w = hit[1] if hit is not None and hit[0] == res_ver.get(mid, 0) \
                else where_of(mid)
            mids.append(mid)
            dms.append(dm)
            wlist.append(w)
            if w:
                v = dm * 1.0 / len(w)
                for k in w:
                    loads[k] = loads.get(k, 1e-6) + v
        if not mids:
            return
        # priorities: only positive ones can be picked, and the pick loop
        # stops at the first non-positive, so non-positive entries are dead
        capacity = self.schedule_ahead * 50  # exec-seconds per horizon unit
        prios = []
        # nothing between the two passes mutates residency, so the pass-1
        # `where` tuples are still exact here
        for i in range(len(mids)):
            mid = mids[i]
            dm = dms[i]
            w = wlist[i]
            if not w:
                p = dm
            else:
                v = dm * 1.0 / len(w)
                fulfilled = 0
                for k in w:
                    f = capacity / loads[k]
                    if f > 1.0:
                        f = 1.0
                    fulfilled += v * f
                p = dm - fulfilled
            if p > 0:
                prios.append((p, mid))
        if not prios:
            return
        prios.sort(reverse=True)

        # `gpus` was filtered on the in-flight LOAD cap up front; a GPU's
        # loading set only grows here through its own send, after which we
        # break — so the filter matches the reference's per-GPU recheck
        for wid, gid, g in gpus:
            resident = g.pagecache.resident
            for p, mid in prios:
                if mid in resident:
                    continue
                model = self.c.models[mid]
                pages = model.pages(g.pagecache.page_bytes)
                if not self._make_room(wid, gid, pages, now):
                    continue
                load_t = self._load_est(mid)
                a = Action(type=ActionType.LOAD, model_id=mid,
                           worker_id=wid, gpu_id=gid, earliest=now,
                           latest=now + self.load_window,
                           expected_duration=load_t)
                self.c.send_action(a)
                break  # one new LOAD per gpu per tick

    def _make_room(self, wid: str, gid: int, pages: int, now: float) -> bool:
        m = self.c.workers[wid]
        g = m.gpus[gid]
        guard = 0
        while g.pagecache.free_pages < pages and guard < 64:
            guard += 1
            active = set(g.loading)
            # don't evict models with pending demand if avoidable
            victim = g.pagecache.lru_candidate(exclude=active | self._active)
            if victim is None:
                victim = g.pagecache.lru_candidate(exclude=active)
            if victim is None:
                return False
            a = Action(type=ActionType.UNLOAD, model_id=victim,
                       worker_id=wid, gpu_id=gid, earliest=now,
                       latest=now + 1.0, expected_duration=1e-5)
            self.c.send_action(a)
        return g.pagecache.free_pages >= pages
