"""Action latency profiles (§5.3 "action profiles").

Per (action type, model, batch size) the controller keeps the last K measured
durations and predicts with the window maximum — the paper's "rolling 99th
percentile" (K=10 makes max == p99+). Seed estimates come from offline
profiling (Table 1 / roofline-derived profiles).
"""
from __future__ import annotations

import collections
from typing import Dict, Tuple

Key = Tuple[str, str, int]          # (action_type, model_id, batch)


class ActionProfiler:
    def __init__(self, window: int = 10, safety: float = 1.0):
        self.window = window
        self.safety = safety
        self._hist: Dict[Key, collections.deque] = {}
        self._seed: Dict[Key, float] = {}
        # prediction-error telemetry for Fig 9
        self.over_errors = []        # predicted - actual  (actual faster)
        self.under_errors = []       # actual - predicted  (actual slower)

    def seed(self, action_type: str, model_id: str, batch: int,
             duration: float):
        self._seed[(action_type, model_id, batch)] = duration

    def observe(self, action_type: str, model_id: str, batch: int,
                duration: float, *, record_error: bool = True):
        key = (action_type, model_id, batch)
        if record_error:
            pred = self.estimate(*key)
            if pred is not None:
                err = pred - duration
                (self.over_errors if err >= 0 else
                 self.under_errors).append(abs(err))
        dq = self._hist.setdefault(key,
                                   collections.deque(maxlen=self.window))
        dq.append(duration)

    def estimate(self, action_type: str, model_id: str, batch: int):
        key = (action_type, model_id, batch)
        dq = self._hist.get(key)
        if dq:
            return max(dq) * self.safety
        s = self._seed.get(key)
        return None if s is None else s * self.safety

    def estimate_or(self, action_type: str, model_id: str, batch: int,
                    default: float) -> float:
        e = self.estimate(action_type, model_id, batch)
        return default if e is None else e

    def history(self) -> Dict[Key, list]:
        """Snapshot of the observation windows — the hook ProfileStore uses
        to fold a live run's measurements back into the persistent store."""
        return {k: list(dq) for k, dq in self._hist.items() if dq}

    def seeds(self) -> Dict[Key, float]:
        return dict(self._seed)

    def known_batches(self, action_type: str, model_id: str):
        out = set()
        for (a, m, b) in self._hist:
            if a == action_type and m == model_id:
                out.add(b)
        for (a, m, b) in self._seed:
            if a == action_type and m == model_id:
                out.add(b)
        return sorted(out)
