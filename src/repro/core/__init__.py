"""Clockwork core: consolidated-choice model serving.

The paper's contribution, adapted to TPU serving (DESIGN.md §2):
  * actions.py    — LOAD/UNLOAD/INFER(+PREFILL/DECODE) with [earliest, latest]
  * clock.py      — virtual/real clocks + the discrete event loop
  * predictor.py  — rolling-p99 action latency profiles (per model, batch)
  * pagecache.py  — paged weight/KV memory accounting
  * worker.py     — predictable worker: per-resource executors, window
                    enforcement, reject-don't-queue straggler mitigation
  * scheduler.py  — the Appendix-B strategy-queue scheduler
  * controller.py — centralized controller: worker mirrors, SLO admission,
                    LOAD priorities, fault detection, elasticity
  * baselines.py  — Clipper-like and INFaaS-like reactive schedulers
"""
from repro.core.actions import (Action, ActionType, Request, Result,
                                ResultStatus)  # noqa: F401
from repro.core.clock import EventLoop, VirtualClock, RealClock  # noqa: F401
from repro.core.controller import Controller  # noqa: F401
from repro.core.pagecache import PageCache  # noqa: F401
from repro.core.predictor import ActionProfiler  # noqa: F401
from repro.core.scheduler import ClockworkScheduler  # noqa: F401
from repro.core.worker import ModelDef, SimBackend, Worker  # noqa: F401
