"""Action / Result / Request types — the controller<->worker contract.

An Action is not an RPC: it communicates either a state change (LOAD/UNLOAD)
or a task with an explicit execution window. A worker MAY begin an action in
[earliest, latest]; outside the window the action is rejected, never executed
late (§4.4 — this is the straggler-mitigation mechanism).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional, Tuple

_action_ids = itertools.count()
_request_ids = itertools.count()


class ActionType(str, enum.Enum):
    LOAD = "LOAD"
    UNLOAD = "UNLOAD"
    INFER = "INFER"      # one-shot inference (CNNs) or a generic forward
    PREFILL = "PREFILL"  # LM serving: context ingestion (len-bucketed)
    DECODE = "DECODE"    # LM serving: one token step for a batch

EXEC_TYPES = (ActionType.INFER, ActionType.PREFILL, ActionType.DECODE)


class ResultStatus(str, enum.Enum):
    SUCCESS = "SUCCESS"
    REJECTED_LATE = "REJECTED_LATE"        # missed [earliest, latest] window
    ERROR_NOT_LOADED = "ERROR_NOT_LOADED"  # INFER without weights resident
    ERROR_NO_PAGES = "ERROR_NO_PAGES"      # LOAD with insufficient free pages
    ERROR_WORKER_DEAD = "ERROR_WORKER_DEAD"


@dataclasses.dataclass
class Request:
    model_id: str
    arrival: float
    slo: float                       # seconds; deadline = arrival + slo
    id: int = dataclasses.field(default_factory=lambda: next(_request_ids))
    batchable: bool = True
    # filled on completion:
    completion: Optional[float] = None
    status: Optional[str] = None     # "ok" | "timeout" | "rejected"

    @property
    def deadline(self) -> float:
        return self.arrival + self.slo


@dataclasses.dataclass
class Action:
    type: ActionType
    model_id: str
    worker_id: str
    gpu_id: int
    earliest: float
    latest: float
    expected_duration: float
    batch_size: int = 1
    request_ids: Tuple[int, ...] = ()
    id: int = dataclasses.field(default_factory=lambda: next(_action_ids))
    issued_at: float = 0.0
    expected_completion: float = 0.0
    received_at: float = 0.0         # stamped by the worker on receipt


@dataclasses.dataclass
class Result:
    action_id: int
    action_type: ActionType
    model_id: str
    worker_id: str
    gpu_id: int
    status: ResultStatus
    t_start: float
    t_end: float
    duration: float                  # on-device execution time
    batch_size: int = 1
    request_ids: Tuple[int, ...] = ()
    t_received: float = 0.0          # worker-side receipt stamp (telemetry)
