"""Centralized controller (§4.5, §5.3).

All decision-making lives here. The controller keeps, per worker:
  * memory state — a PageCache *mirror* updated optimistically on LOAD/UNLOAD
    submission and reconciled on results,
  * action profiles — rolling-window duration estimates (predictor.py),
  * pending actions — per-executor availability estimates.

It delegates policy to a pluggable Scheduler (scheduler.py implements the
paper's; baselines.py the reactive comparisons) — "this design concentrates
all choice in a single place, and enables different scheduler implementations
to be easily dropped in" (§5.3).

Fault tolerance (beyond the paper, §7 "future work"): heartbeats + missing-
result detection mark workers dead; their mirrors are dropped, outstanding
requests re-queued, and the LOAD-priority machinery re-replicates their
models elsewhere. Workers can be added/removed at runtime (elasticity).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional

from repro.core.actions import (EXEC_TYPES, Action, ActionType, Request,
                                Result, ResultStatus)
from repro.core.clock import EventLoop
from repro.core.pagecache import PageCache
from repro.core.predictor import ActionProfiler
from repro.core.worker import ModelDef, Worker
from repro.telemetry.recorder import Recorder
from repro.telemetry.reports import summarize_run


@dataclasses.dataclass
class GpuMirror:
    pagecache: PageCache
    loading: set = dataclasses.field(default_factory=set)
    exec_free_at: float = 0.0
    load_free_at: float = 0.0
    # expected completion of in-flight actions by lane (action_id -> t);
    # replaces the per-result scan over every outstanding action
    pending_exec: Dict[int, float] = dataclasses.field(default_factory=dict)
    pending_load: Dict[int, float] = dataclasses.field(default_factory=dict)


class WorkerMirror:
    def __init__(self, worker: Worker):
        self.worker = worker
        self.worker_id = worker.worker_id
        self.alive = True
        self.gpus: List[GpuMirror] = [
            GpuMirror(pagecache=PageCache(
                pc.total_pages * pc.page_bytes, pc.page_bytes))
            for pc in worker.pagecaches
        ]
        self.outstanding: Dict[int, Action] = {}
        self.missed_results = 0
        # estimated one-way network delay to this worker (seconds). 0 for
        # in-process workers; for remote workers the runtime keeps it fresh
        # from heartbeat RTTs (§5 network-delay treatment) and the scheduler's
        # action windows widen by it in send_action.
        self.net_delay = 0.0

    def gpu_ids(self):
        return range(len(self.gpus))


class Controller:
    def __init__(self, loop: EventLoop, models: Dict[str, ModelDef],
                 scheduler, *, action_delay: float = 0.0005,
                 heartbeat_interval: float = 1.0,
                 heartbeat_timeout: float = 0.5,
                 result_grace: float = 0.050,
                 default_slo: float = 0.100,
                 missed_result_threshold: int = 2,
                 recorder: Optional[Recorder] = None):
        self.loop = loop
        self.models = models
        self.scheduler = scheduler
        self.action_delay = action_delay
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        self.result_grace = result_grace
        self.default_slo = default_slo
        self.missed_result_threshold = missed_result_threshold

        self.workers: Dict[str, WorkerMirror] = {}
        self.profiler = ActionProfiler()
        self.requests: Dict[int, Request] = {}
        # cluster-wide residency index over the mirrors: model -> set of
        # (worker_id, gpu_id); kept in sync by PageCache change hooks so the
        # scheduler's LOAD allocation never scans every GPU per model.
        # _gpu_ord ranks GPU keys in worker-registration order so index
        # lookups can be ordered exactly like a scan over the workers dict.
        self._residency: Dict[str, set] = {}
        self._res_ver: Dict[str, int] = {}   # bumped on any residency change
        self._gpu_ord: Dict[tuple, int] = {}
        self._gpu_ord_seq = 0
        self.on_response: Optional[Callable[[Request], None]] = None
        self.tick_interval = 0.001
        self._ticker_on = False
        # missed-result timer wheel: one armed sweep over a deadline heap
        # instead of one scheduled closure per action (heartbeat timeouts
        # ride the same mechanism via _arm_watch)
        self._watch_heap: List[tuple] = []    # (t, seq, kind, payload)
        self._watch_next = float("inf")       # earliest armed sweep time
        self._watch_seq = itertools.count()

        # telemetry
        self.recorder = recorder if recorder is not None else Recorder()
        self.completed: List[Request] = []
        self.results_log: List[Result] = []
        self.stats = {"goodput": 0, "timeout": 0, "rejected": 0,
                      "cold_starts": 0, "actions": 0, "dead_workers": 0}

        scheduler.attach(self)

    # ------------------------------------------------------------ workers
    def add_worker(self, worker: Worker, profiles: Optional[dict] = None):
        """Register a worker; `profiles` seeds (type, model, batch)->secs."""
        m = WorkerMirror(worker)
        self.workers[worker.worker_id] = m
        worker.on_result = self.on_result
        for gid in m.gpu_ids():
            key = (worker.worker_id, gid)
            self._gpu_ord[key] = self._gpu_ord_seq
            self._gpu_ord_seq += 1
            m.gpus[gid].pagecache.on_resident_change = \
                self._residency_hook(key)
        if profiles:
            for (t, mid, b), d in profiles.items():
                self.profiler.seed(t, mid, b, d)
        self.scheduler.on_topology_change()
        return m

    def seed_from_store(self, store):
        """Seed action profiles from a persistent ProfileStore — the
        startup path that replaces per-process warmup re-measurement."""
        store.seed_profiler(self.profiler)
        # new seeds invalidate any estimates the scheduler has cached
        self.scheduler.on_topology_change()

    def remove_worker(self, worker_id: str):
        """Graceful removal (elastic scale-down)."""
        self._kill_mirror(worker_id, graceful=True)

    def _residency_hook(self, key):
        def hook(model_id: str, added: bool):
            self._res_ver[model_id] = self._res_ver.get(model_id, 0) + 1
            if added:
                s = self._residency.get(model_id)
                if s is None:
                    s = self._residency[model_id] = set()
                s.add(key)
            else:
                s = self._residency.get(model_id)
                if s is not None:
                    s.discard(key)
                    if not s:
                        del self._residency[model_id]
        return hook

    def residency_where(self, model_id: str):
        """GPU keys holding `model_id`, ordered exactly as a scan over the
        workers dict (registration order) would list them."""
        s = self._residency.get(model_id)
        if not s:
            return ()
        if len(s) == 1:
            return tuple(s)
        return sorted(s, key=self._gpu_ord.__getitem__)

    def _kill_mirror(self, worker_id: str, graceful: bool = False):
        m = self.workers.pop(worker_id, None)
        if m is None:
            return
        if not graceful:
            self.stats["dead_workers"] += 1
        # purge the dead mirror's GPUs from the residency index
        for gid in m.gpu_ids():
            g = m.gpus[gid]
            g.pagecache.on_resident_change = None
            key = (worker_id, gid)
            for mid in g.pagecache.resident:
                self._res_ver[mid] = self._res_ver.get(mid, 0) + 1
                s = self._residency.get(mid)
                if s is not None:
                    s.discard(key)
                    if not s:
                        del self._residency[mid]
            self._gpu_ord.pop(key, None)
        # re-queue outstanding exec requests if their deadline still allows
        for a in m.outstanding.values():
            for rid in a.request_ids:
                req = self.requests.get(rid)
                if req is not None and req.status is None:
                    self.scheduler.requeue(req)
        self.scheduler.on_topology_change()
        self.scheduler.tick()
        self._ensure_ticker()

    def worker_failed(self, worker_id: str):
        self._kill_mirror(worker_id, graceful=False)

    def start_heartbeats(self):
        def beat():
            for wid, m in list(self.workers.items()):
                ok = {"v": False}

                def pong(ok=ok):
                    ok["v"] = True

                m.worker.ping(pong)

                def check(wid=wid, ok=ok):
                    if not ok["v"]:
                        self.worker_failed(wid)

                self.watch_at(self.loop.now() + self.heartbeat_timeout,
                              check)
            self.loop.schedule_in(self.heartbeat_interval, beat)

        self.loop.schedule_in(self.heartbeat_interval, beat)

    def observe_net_delay(self, worker_id: str, rtt: float,
                          alpha: float = 0.2):
        """Fold a measured heartbeat round-trip into the worker's one-way
        network-delay estimate (EWMA). The runtime's ControllerServer calls
        this on every PONG; send_action widens expected starts and
        missed-result deadlines by the estimate."""
        m = self.workers.get(worker_id)
        if m is None or rtt < 0:
            return
        sample = rtt / 2.0
        if m.net_delay == 0.0:
            m.net_delay = sample
        else:
            m.net_delay = (1.0 - alpha) * m.net_delay + alpha * sample

    # ------------------------------------------------------- timer wheel
    # One armed `loop.schedule` sweeps a deadline heap, replacing the
    # per-action closure the missed-result detector used to schedule (and
    # the per-beat heartbeat-timeout closures, which share the wheel via
    # `watch_at`). Entries are (t, seq, kind, payload); seq keeps payloads
    # out of tuple comparison.
    _WATCH_ACTION, _WATCH_FN = 0, 1

    def _arm_watch(self, t: float):
        if t < self._watch_next:
            self._watch_next = t
            self.loop.schedule(t, self._watch_sweep)

    def watch_at(self, t: float, fn: Callable[[], None]):
        """Run `fn` once at time `t` via the shared timer-wheel sweep."""
        heapq.heappush(self._watch_heap,
                       (t, next(self._watch_seq), self._WATCH_FN, fn))
        self._arm_watch(t)

    def _watch_action_at(self, t: float, action_id: int, worker_id: str):
        heapq.heappush(self._watch_heap,
                       (t, next(self._watch_seq), self._WATCH_ACTION,
                        (action_id, worker_id)))
        self._arm_watch(t)

    def _watch_sweep(self):
        now = self.loop.now()
        if now + 1e-12 < self._watch_next:
            return  # superseded wakeup; an earlier re-arm already swept
        self._watch_next = float("inf")
        heap = self._watch_heap
        while heap and heap[0][0] <= now + 1e-12:
            _, _, kind, payload = heapq.heappop(heap)
            if kind == self._WATCH_ACTION:
                aid, wid = payload
                mm = self.workers.get(wid)
                if mm is not None and aid in mm.outstanding:
                    mm.missed_results += 1
                    if mm.missed_results >= self.missed_result_threshold:
                        self.worker_failed(wid)
            else:
                payload()
        if heap:
            self._arm_watch(heap[0][0])

    # ------------------------------------------------------------ requests
    def _has_pending(self) -> bool:
        hp = getattr(self.scheduler, "has_pending", None)
        if hp is not None:
            return hp()
        return any(self.scheduler.queues.values())

    def _ticker(self):
        """Periodic scheduler drive while work is pending (the event-driven
        stand-in for Clockwork's continuously-running scheduler thread)."""
        self.scheduler.tick()
        if self._has_pending():
            self.loop.schedule_in(self.tick_interval, self._ticker)
        else:
            self._ticker_on = False

    def _ensure_ticker(self):
        if not self._ticker_on:
            self._ticker_on = True
            self.loop.schedule_in(self.tick_interval, self._ticker)

    def on_request(self, req: Request):
        self.requests[req.id] = req
        self.recorder.span_open(req, queued=self.loop.now())
        self.scheduler.on_request(req)
        self.scheduler.tick()
        self._ensure_ticker()

    def reject(self, req: Request, when: Optional[float] = None):
        if req.status is not None:
            return
        req.status = "rejected"
        req.completion = when if when is not None else self.loop.now()
        self.stats["rejected"] += 1
        self.completed.append(req)
        self.recorder.span_close(req, req.completion)
        if self.on_response:
            self.on_response(req)

    def complete(self, req: Request, when: float):
        if req.status is not None:
            return
        req.completion = when
        if when <= req.deadline + 1e-9:
            req.status = "ok"
            self.stats["goodput"] += 1
        else:
            req.status = "timeout"
            self.stats["timeout"] += 1
        self.completed.append(req)
        self.recorder.span_close(req, when)
        if self.on_response:
            self.on_response(req)

    # ------------------------------------------------------------ actions
    def send_action(self, action: Action):
        m = self.workers.get(action.worker_id)
        if m is None:
            return
        now = self.loop.now()
        action.issued_at = now
        g = m.gpus[action.gpu_id]
        # one-way send estimate: controller-side dispatch overhead plus the
        # worker's estimated network delay (0 for in-process workers) — the
        # paper's §5 treatment of network delay in action windows
        send_est = self.action_delay + m.net_delay
        # pending-actions model: an executor starts this action no earlier
        # than when its already-submitted work completes
        if action.type == ActionType.LOAD:
            start = max(now + send_est, action.earliest,
                        g.load_free_at)
        else:
            start = max(now + send_est, action.earliest,
                        g.exec_free_at)
        action.expected_completion = start + action.expected_duration
        # optimistic mirror updates (reconciled on result)
        if action.type == ActionType.LOAD:
            model = self.models[action.model_id]
            g.pagecache.alloc(action.model_id,
                              model.pages(g.pagecache.page_bytes))
            g.loading.add(action.model_id)
            g.load_free_at = action.expected_completion
            g.pending_load[action.id] = action.expected_completion
        elif action.type == ActionType.UNLOAD:
            g.pagecache.free(action.model_id)
        elif action.type in EXEC_TYPES:
            g.pagecache.touch(action.model_id)
            g.exec_free_at = action.expected_completion
            g.pending_exec[action.id] = action.expected_completion
            self.recorder.span_dispatch(action.request_ids, now,
                                        action.worker_id, action.gpu_id,
                                        action.batch_size)
        m.outstanding[action.id] = action
        self.stats["actions"] += 1
        # the schedule_in below models only the controller-side dispatch;
        # for remote workers the transport itself adds the network leg
        self.loop.schedule_in(self.action_delay,
                              lambda: m.worker.receive(action))
        # missing-result failure detection via the shared timer wheel
        # (deadline covers both network legs: send_est out, net_delay back)
        if action.type != ActionType.UNLOAD:
            deadline = action.expected_completion + self.result_grace \
                + self.action_delay + send_est
            self._watch_action_at(max(deadline, action.latest
                                      + action.expected_duration
                                      + self.result_grace),
                                  action.id, action.worker_id)

    def on_result(self, result: Result):
        self.results_log.append(result)
        m = self.workers.get(result.worker_id)
        action = None
        if m is not None:
            action = m.outstanding.pop(result.action_id, None)
            m.missed_results = 0     # the worker is responsive again
            g = m.gpus[result.gpu_id]
            if result.action_type == ActionType.LOAD:
                g.loading.discard(result.model_id)
                if result.status is not ResultStatus.SUCCESS:
                    g.pagecache.free(result.model_id)  # reconcile mirror
                g.pending_load.pop(result.action_id, None)
                g.load_free_at = max(g.pending_load.values(),
                                     default=result.t_end)
            elif result.action_type in EXEC_TYPES:
                g.pending_exec.pop(result.action_id, None)
                g.exec_free_at = max(g.pending_exec.values(),
                                     default=result.t_end)
        # telemetry: predicted-vs-actual record + span phase stamps
        predicted = action.expected_duration if action is not None else None
        self.recorder.record_action(result, predicted)
        if result.status is ResultStatus.SUCCESS:
            if result.action_type in EXEC_TYPES:
                self.recorder.span_exec(result.request_ids, result.t_start,
                                        result.t_end)
            elif result.action_type == ActionType.LOAD:
                self.recorder.span_load(result.model_id, result.t_start,
                                        result.t_end)
        if result.status is ResultStatus.SUCCESS and result.duration > 0:
            self.profiler.observe(result.action_type.value, result.model_id,
                                  result.batch_size, result.duration)
        # request completion / re-queue
        for rid in result.request_ids:
            req = self.requests.get(rid)
            if req is None:
                continue
            if result.status is ResultStatus.SUCCESS:
                self.complete(req, result.t_end)
            else:
                self.scheduler.requeue(req)
        self.scheduler.on_result(result)
        self.scheduler.tick()
        if self._has_pending():
            self._ensure_ticker()

    # ------------------------------------------------------------ helpers
    def loaded_gpus(self, model_id: str):
        """(worker_id, gpu_id) pairs where model is resident or loading."""
        out = []
        for wid, m in self.workers.items():
            for gid in m.gpu_ids():
                g = m.gpus[gid]
                if g.pagecache.contains(model_id):
                    out.append((wid, gid))
        return out

    def summary(self) -> dict:
        lat = [r.completion - r.arrival for r in self.completed
               if r.status == "ok"]
        lat.sort()

        def pct(q):
            if not lat:
                return float("nan")
            i = min(len(lat) - 1, int(q * (len(lat) - 1)))
            return lat[i]

        return dict(self.stats, total=len(self.completed),
                    p50=pct(0.50), p99=pct(0.99), p999=pct(0.999),
                    max=lat[-1] if lat else float("nan"))

    def telemetry_report(self) -> dict:
        """Latency breakdown + prediction-error summary from the Recorder."""
        return summarize_run(self.recorder)
