"""Reactive baseline schedulers for the Fig-5 comparison.

These run on the *same* controller/worker substrate as Clockwork, differing
only in policy — i.e. we compare scheduling disciplines, not implementations:

* ClipperScheduler — best-effort, work-conserving: requests dispatched
  immediately round-robin, per-model AIMD adaptive batching toward the SLO as
  an *average* target, on-demand LOAD, actions never rejected
  (latest = +inf). Tail latency propagates via queueing (§3 "stragglers").

* InfaasScheduler — reactive model-variant selection: picks a batch-size
  variant per model from recent load, rebalances to the least-loaded GPU on a
  monitoring interval; SLOs are coarse thresholds for variant switching.
"""
from __future__ import annotations

import collections
import itertools
from typing import Deque, Dict

from repro.core.actions import Action, ActionType, Request, Result, ResultStatus

INF = float("inf")


class _ReactiveBase:
    def __init__(self, *, action_type: ActionType = ActionType.INFER,
                 horizon: float = 0.005):
        self.action_type = action_type
        self.horizon = horizon
        self.c = None
        self.queues: Dict[str, Deque[Request]] = collections.defaultdict(
            collections.deque)
        self._rr = itertools.count()
        self._in_tick = False

    def attach(self, controller):
        self.c = controller

    def on_topology_change(self):
        pass

    def on_request(self, req: Request):
        self.queues[req.model_id].append(req)

    def requeue(self, req: Request):
        if req.status is None:
            self.queues[req.model_id].appendleft(req)

    def on_result(self, result: Result):
        pass

    def _gpus(self):
        out = []
        for wid, m in self.c.workers.items():
            for gid in m.gpu_ids():
                out.append((wid, gid, m.gpus[gid]))
        return out

    def _ensure_loaded(self, mid: str, wid: str, gid: int, g, now: float):
        if g.pagecache.contains(mid):
            return True
        model = self.c.models[mid]
        pages = model.pages(g.pagecache.page_bytes)
        guard = 0
        while g.pagecache.free_pages < pages and guard < 64:
            guard += 1
            victim = g.pagecache.lru_candidate(exclude=g.loading)
            if victim is None:
                return False
            self.c.send_action(Action(
                type=ActionType.UNLOAD, model_id=victim, worker_id=wid,
                gpu_id=gid, earliest=now, latest=INF,
                expected_duration=1e-5))
        self.c.send_action(Action(
            type=ActionType.LOAD, model_id=mid, worker_id=wid, gpu_id=gid,
            earliest=now, latest=INF,
            expected_duration=1e-3 + model.weights_bytes / 25e9))
        return False  # not yet resident; exec will follow next tick

    def _send_exec(self, mid: str, reqs, wid: str, gid: int, now: float):
        est = self.c.profiler.estimate_or(self.action_type.value, mid,
                                          len(reqs), 0.005 * len(reqs))
        self.c.send_action(Action(
            type=self.action_type, model_id=mid, worker_id=wid, gpu_id=gid,
            earliest=now, latest=INF, expected_duration=est,
            batch_size=len(reqs), request_ids=tuple(r.id for r in reqs)))


class ClipperScheduler(_ReactiveBase):
    def __init__(self, **kw):
        super().__init__(**kw)
        # multiplicative backoff factor per model (AIMD around the profile)
        self.scale: Dict[str, float] = collections.defaultdict(lambda: 1.0)

    def _batch_for(self, mid: str, slo: float) -> int:
        """Clipper's adaptive batching: largest batch whose (profiled) batch
        latency fits the SLO, AIMD-adjusted by observed violations."""
        allowed = slo * 0.7 * self.scale[mid]
        best = 1
        for b in (1, 2, 4, 8, 16):
            est = self.c.profiler.estimate_or(self.action_type.value, mid, b,
                                              0.005 * b)
            if est <= allowed:
                best = b
        return best

    def on_result(self, result: Result):
        if result.status is not ResultStatus.SUCCESS or not result.request_ids:
            return
        mid = result.model_id
        for rid in result.request_ids:
            req = self.c.requests.get(rid)
            if req is None or req.completion is None:
                continue
            lat = req.completion - req.arrival
            if lat > req.slo:
                self.scale[mid] = max(0.1, self.scale[mid] * 0.9)
            else:
                self.scale[mid] = min(1.0, self.scale[mid] + 0.02)

    def tick(self):
        if self.c is None or self._in_tick:
            return
        self._in_tick = True
        try:
            now = self.c.loop.now()
            gpus = self._gpus()
            if not gpus:
                return
            for mid, q in self.queues.items():
                while q:
                    wid, gid, g = gpus[next(self._rr) % len(gpus)]
                    if g.exec_free_at > now + self.horizon:
                        full = all(gg.exec_free_at > now + self.horizon
                                   for _, _, gg in gpus)
                        if full:
                            return
                        continue
                    if not self._ensure_loaded(mid, wid, gid, g, now):
                        break
                    b = self._batch_for(mid, q[0].slo)
                    take = min(b, len(q))
                    reqs = [q.popleft() for _ in range(take)]
                    self._send_exec(mid, reqs, wid, gid, now)
        finally:
            self._in_tick = False


class InfaasScheduler(_ReactiveBase):
    """Variant selection by recent arrival rate; least-loaded placement."""

    def __init__(self, monitor_interval: float = 0.010, **kw):
        super().__init__(**kw)
        self.monitor_interval = monitor_interval
        self.rate_ewma: Dict[str, float] = collections.defaultdict(float)
        self._last_arrival: Dict[str, float] = {}

    def on_request(self, req: Request):
        super().on_request(req)
        t = self._last_arrival.get(req.model_id)
        now = req.arrival
        if t is not None and now > t:
            inst = 1.0 / (now - t)
            self.rate_ewma[req.model_id] = (0.9 * self.rate_ewma[req.model_id]
                                            + 0.1 * inst)
        self._last_arrival[req.model_id] = now

    def _variant(self, mid: str, slo: float) -> int:
        # largest batch variant whose exec time fits half the SLO; only
        # upgrade past batch-4 when the arrival rate sustains it
        best = 1
        for b in (1, 2, 4, 8, 16):
            est = self.c.profiler.estimate_or(self.action_type.value, mid, b,
                                              0.005 * b)
            if est <= slo * 0.5 and (b <= 4 or
                                     self.rate_ewma[mid] * est >= b * 0.25):
                best = b
        return best

    def tick(self):
        if self.c is None or self._in_tick:
            return
        self._in_tick = True
        try:
            now = self.c.loop.now()
            gpus = self._gpus()
            if not gpus:
                return
            for mid, q in self.queues.items():
                while q:
                    # least-loaded gpu
                    wid, gid, g = min(gpus, key=lambda x: x[2].exec_free_at)
                    if g.exec_free_at > now + self.horizon:
                        return
                    if not self._ensure_loaded(mid, wid, gid, g, now):
                        break
                    b = self._variant(mid, q[0].slo)
                    take = min(b, len(q))
                    reqs = [q.popleft() for _ in range(take)]
                    self._send_exec(mid, reqs, wid, gid, now)
        finally:
            self._in_tick = False
