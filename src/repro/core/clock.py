"""Clocks + the discrete event loop.

The same controller/worker/scheduler code runs under either clock:
  * VirtualClock — discrete-event simulation (paper-scale experiments:
    thousands of models, millions of requests, replayed in seconds)
  * RealClock    — wall time; event callbacks execute JAX programs
    (quickstart / engine demos on the local device)

`RealtimePump` drives an EventLoop on a real clock while accepting
callbacks posted from other threads — the bridge the distributed runtime
(`repro.runtime`) needs so TCP reader threads can hand frames to the
single-threaded controller/worker event loop.
"""
from __future__ import annotations

import heapq
import itertools
import queue
import time
from typing import Callable, Optional


class VirtualClock:
    def __init__(self, start: float = 0.0):
        self._now = start

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float):
        assert t >= self._now - 1e-12, (t, self._now)
        self._now = max(self._now, t)


class RealClock:
    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def advance_to(self, t: float):
        delta = t - self.now()
        if delta > 0:
            time.sleep(delta)


class EventLoop:
    """Priority-queue event loop shared by simulation and real execution."""

    def __init__(self, clock=None):
        self.clock = clock if clock is not None else VirtualClock()
        self._heap = []
        self._seq = itertools.count()
        # throughput telemetry: events dispatched + wall time spent inside
        # run_until/run_all (virtual-clock runs: simulated events per wall s)
        self.events_total = 0
        self.wall_busy_s = 0.0

    def now(self) -> float:
        return self.clock.now()

    def schedule(self, t: float, fn: Callable[[], None]):
        heapq.heappush(self._heap, (max(t, self.now()), next(self._seq), fn))

    def schedule_in(self, dt: float, fn: Callable[[], None]):
        self.schedule(self.now() + dt, fn)

    def empty(self) -> bool:
        return not self._heap

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def run_until(self, t_end: float, max_events: int = 100_000_000):
        heap = self._heap
        pop = heapq.heappop
        advance = self.clock.advance_to
        n = 0
        t0 = time.perf_counter()
        while heap and heap[0][0] <= t_end and n < max_events:
            t, _, fn = pop(heap)
            advance(t)
            fn()
            n += 1
        advance(t_end)
        self.events_total += n
        self.wall_busy_s += time.perf_counter() - t0
        return n

    def run_all(self, max_events: int = 100_000_000):
        heap = self._heap
        pop = heapq.heappop
        advance = self.clock.advance_to
        n = 0
        t0 = time.perf_counter()
        while heap and n < max_events:
            t, _, fn = pop(heap)
            advance(t)
            fn()
            n += 1
        self.events_total += n
        self.wall_busy_s += time.perf_counter() - t0
        return n

    def stats(self) -> dict:
        """Event-loop throughput gauges for telemetry reports."""
        w = self.wall_busy_s
        return {"events_total": self.events_total,
                "wall_busy_s": w,
                "events_per_wall_s": (self.events_total / w) if w > 0
                else 0.0}


class RealtimePump:
    """Single-threaded driver for an EventLoop under wall time that also
    accepts cross-thread work.

    The EventLoop itself is not thread-safe; transport reader threads must
    never touch it directly. Instead they `post(fn)` and the pump runs `fn`
    on the loop thread between event dispatches. The pump sleeps no longer
    than `max_poll` (so `stop()` is honored promptly) or until the next
    scheduled event, whichever is sooner.
    """

    def __init__(self, loop: EventLoop, max_poll: float = 0.02):
        self.loop = loop
        self.max_poll = max_poll
        self._inbox: "queue.Queue[Callable[[], None]]" = queue.Queue()
        self._stop = False

    def post(self, fn: Callable[[], None]) -> None:
        """Thread-safe: run `fn` on the pump thread as soon as possible."""
        self._inbox.put(fn)

    def stop(self) -> None:
        self._stop = True
        self._inbox.put(lambda: None)     # wake a sleeping pump

    def pump_once(self) -> None:
        """One iteration: run due events, then wait briefly for posted work
        (at most until the next scheduled event or `max_poll`)."""
        loop = self.loop
        nxt = loop.peek_time()
        now = loop.now()
        if nxt is not None and nxt <= now:
            loop.run_until(now)
            self._drain_inbox()
            return
        timeout = self.max_poll if nxt is None \
            else min(self.max_poll, max(0.0, nxt - now))
        try:
            fn = self._inbox.get(timeout=timeout)
        except queue.Empty:
            return
        fn()
        self._drain_inbox()

    def _drain_inbox(self) -> None:
        while True:
            try:
                fn = self._inbox.get_nowait()
            except queue.Empty:
                return
            fn()

    def run(self, until: Optional[Callable[[], bool]] = None,
            timeout: Optional[float] = None) -> bool:
        """Pump until `until()` is true, `timeout` seconds elapse, or
        `stop()` is called. Returns whether `until` was satisfied."""
        t_end = None if timeout is None else self.loop.now() + timeout
        while not self._stop:
            if until is not None and until():
                return True
            if t_end is not None and self.loop.now() >= t_end:
                return until() if until is not None else False
            self.pump_once()
        return until() if until is not None else False

    def run_for(self, seconds: float) -> None:
        self.run(timeout=seconds)
