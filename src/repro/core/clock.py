"""Clocks + the discrete event loop.

The same controller/worker/scheduler code runs under either clock:
  * VirtualClock — discrete-event simulation (paper-scale experiments:
    thousands of models, millions of requests, replayed in seconds)
  * RealClock    — wall time; event callbacks execute JAX programs
    (quickstart / engine demos on the local device)
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, Optional


class VirtualClock:
    def __init__(self, start: float = 0.0):
        self._now = start

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float):
        assert t >= self._now - 1e-12, (t, self._now)
        self._now = max(self._now, t)


class RealClock:
    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def advance_to(self, t: float):
        delta = t - self.now()
        if delta > 0:
            time.sleep(delta)


class EventLoop:
    """Priority-queue event loop shared by simulation and real execution."""

    def __init__(self, clock=None):
        self.clock = clock if clock is not None else VirtualClock()
        self._heap = []
        self._seq = itertools.count()
        # throughput telemetry: events dispatched + wall time spent inside
        # run_until/run_all (virtual-clock runs: simulated events per wall s)
        self.events_total = 0
        self.wall_busy_s = 0.0

    def now(self) -> float:
        return self.clock.now()

    def schedule(self, t: float, fn: Callable[[], None]):
        heapq.heappush(self._heap, (max(t, self.now()), next(self._seq), fn))

    def schedule_in(self, dt: float, fn: Callable[[], None]):
        self.schedule(self.now() + dt, fn)

    def empty(self) -> bool:
        return not self._heap

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def run_until(self, t_end: float, max_events: int = 100_000_000):
        heap = self._heap
        pop = heapq.heappop
        advance = self.clock.advance_to
        n = 0
        t0 = time.perf_counter()
        while heap and heap[0][0] <= t_end and n < max_events:
            t, _, fn = pop(heap)
            advance(t)
            fn()
            n += 1
        advance(t_end)
        self.events_total += n
        self.wall_busy_s += time.perf_counter() - t0
        return n

    def run_all(self, max_events: int = 100_000_000):
        heap = self._heap
        pop = heapq.heappop
        advance = self.clock.advance_to
        n = 0
        t0 = time.perf_counter()
        while heap and n < max_events:
            t, _, fn = pop(heap)
            advance(t)
            fn()
            n += 1
        self.events_total += n
        self.wall_busy_s += time.perf_counter() - t0
        return n

    def stats(self) -> dict:
        """Event-loop throughput gauges for telemetry reports."""
        w = self.wall_busy_s
        return {"events_total": self.events_total,
                "wall_busy_s": w,
                "events_per_wall_s": (self.events_total / w) if w > 0
                else 0.0}
