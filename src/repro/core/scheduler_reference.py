"""Pre-optimization Clockwork scheduler, frozen verbatim (PR 2).

This is the O(models x batches) implementation that rebuilds the full
strategy list after every scheduled action. It is kept for two reasons:

  * the decision-equivalence regression test runs it side by side with the
    incremental `repro.core.scheduler.ClockworkScheduler` on seeded
    workloads and asserts identical goodput/timeout/reject counts, and
  * `benchmarks/bench_scheduler.py --compare` measures the speedup of the
    incremental implementation against it (BENCH_scheduler.json).

Do not optimize this file; its value is being the unoptimized baseline.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.actions import (Action, ActionType, Request, Result,
                                ResultStatus)

DEFAULT_BATCHES = (1, 2, 4, 8, 16)


class ReferenceClockworkScheduler:
    def __init__(self, *, schedule_ahead: float = 0.005,
                 batch_sizes=DEFAULT_BATCHES,
                 action_type: ActionType = ActionType.INFER,
                 load_window: float = 0.250,
                 max_loads_in_flight_per_gpu: int = 2):
        self.schedule_ahead = schedule_ahead
        self.batch_sizes = tuple(sorted(batch_sizes))
        self.action_type = action_type
        self.load_window = load_window
        self.max_loads = max_loads_in_flight_per_gpu
        self.c: Optional["Controller"] = None
        self.queues: Dict[str, Deque[Request]] = collections.defaultdict(
            collections.deque)
        self._in_tick = False

    # ---------------------------------------------------------- interface
    def attach(self, controller):
        self.c = controller

    def on_topology_change(self):
        pass

    def on_request(self, req: Request):
        self.queues[req.model_id].append(req)

    def requeue(self, req: Request):
        if req.status is not None:
            return
        q = self.queues[req.model_id]
        q.appendleft(req)

    def on_result(self, result: Result):
        pass

    # ---------------------------------------------------------- estimates
    def _est(self, model_id: str, b: int) -> Optional[float]:
        return self.c.profiler.estimate(self.action_type.value, model_id, b)

    def _est_or_scale(self, model_id: str, b: int) -> float:
        e = self._est(model_id, b)
        if e is not None:
            return e
        e1 = self.c.profiler.estimate_or(self.action_type.value, model_id, 1,
                                         0.005)
        return e1 * b

    def _load_est(self, model_id: str) -> float:
        e = self.c.profiler.estimate("LOAD", model_id, 1)
        if e is not None:
            return e
        mdl = self.c.models[model_id]
        return 1e-3 + mdl.weights_bytes / 25e9

    # ---------------------------------------------------------- main loop
    def tick(self):
        if self.c is None or self._in_tick:
            return
        self._in_tick = True
        try:
            now = self.c.loop.now()
            self._drop_hopeless(now)
            self._schedule_exec(now)
            self._schedule_loads(now)
        finally:
            self._in_tick = False

    # Drop requests that can no longer meet their SLO anywhere (§4.1: cancel
    # before fruitless work).
    def _drop_hopeless(self, now: float):
        for mid, q in self.queues.items():
            while q:
                changed = False
                for i, r in enumerate(q):
                    if r.status is not None:
                        del q[i]
                        changed = True
                        break
                    if r.deadline - self._est_or_scale(mid, 1) < now:
                        self.c.reject(r)
                        del q[i]
                        changed = True
                        break
                if not changed:
                    break

    def _strategies(self, now: float) -> List[Tuple[float, str, int]]:
        """(required_start, model, batch) sorted; best per (model, batch)."""
        out = []
        for mid, q in self.queues.items():
            if not q:
                continue
            n = len(q)
            for b in self.batch_sizes:
                if b > n and b != self.batch_sizes[0]:
                    continue
                eff_b = min(b, n)
                exec_t = self._est_or_scale(mid, b)
                dl = min(q[i].deadline for i in range(eff_b))
                out.append((dl - exec_t, mid, b))
        out.sort()
        return out

    def _schedule_exec(self, now: float):
        strategies = self._strategies(now)
        if not strategies:
            return
        for wid, m in self.c.workers.items():
            for gid in m.gpu_ids():
                g = m.gpus[gid]
                while g.exec_free_at < now + self.schedule_ahead:
                    picked = self._pick_strategy(strategies, now, g)
                    if picked is None:
                        break
                    req_start, mid, b = picked
                    q = self.queues[mid]
                    take = min(b, len(q))
                    reqs = [q.popleft() for _ in range(take)]
                    exec_t = self._est_or_scale(mid, take)
                    dl = min(r.deadline for r in reqs)
                    start_at = max(now, g.exec_free_at)
                    a = Action(type=self.action_type, model_id=mid,
                               worker_id=wid, gpu_id=gid,
                               earliest=now, latest=max(now, dl - exec_t),
                               expected_duration=exec_t, batch_size=take,
                               request_ids=tuple(r.id for r in reqs))
                    self.c.send_action(a)
                    strategies = self._strategies(now)
                    if not strategies:
                        return

    def _pick_strategy(self, strategies, now: float, g) -> Optional[tuple]:
        avail = max(now, g.exec_free_at)
        seen_models = set()
        for (req_start, mid, b) in strategies:
            q = self.queues.get(mid)
            if not q:
                continue
            if not (g.pagecache.contains(mid) and mid not in g.loading):
                continue  # not resident on this executor's GPU
            if mid in seen_models:
                continue  # a larger batch for this model was already viable
            if b > len(q) and b != self.batch_sizes[0]:
                continue
            exec_t = self._est_or_scale(mid, min(b, len(q)))
            dl = min(q[i].deadline for i in range(min(b, len(q))))
            if avail + exec_t > dl:
                # cannot finish in time on this executor
                seen_models.add(mid)
                continue
            # prefer larger batch: check if a larger batch is also feasible
            return (req_start, mid, b)
        return None

    # ---------------------------------------------------------- LOAD/UNLOAD
    def _demands(self) -> Dict[str, float]:
        d = {}
        for mid, q in self.queues.items():
            if q:
                d[mid] = sum(self._est_or_scale(mid, 1) for _ in range(len(q)))
        return d

    def _schedule_loads(self, now: float):
        demands = self._demands()
        if not demands:
            return
        # GPU loads l_g: demand allocated to each gpu
        gpu_keys = []
        for wid, m in self.c.workers.items():
            for gid in m.gpu_ids():
                gpu_keys.append((wid, gid))
        if not gpu_keys:
            return
        loads = {k: 1e-6 for k in gpu_keys}
        allocs: Dict[str, Dict[tuple, float]] = {}
        for mid, dm in demands.items():
            where = [k for k in gpu_keys
                     if self.c.workers[k[0]].gpus[k[1]].pagecache.contains(mid)]
            if not where:
                continue
            inv = {k: 1.0 for k in where}
            tot = sum(inv.values())
            allocs[mid] = {k: dm * inv[k] / tot for k in where}
            for k, v in allocs[mid].items():
                loads[k] += v
        # priorities
        capacity = self.schedule_ahead * 50  # exec-seconds per horizon unit
        prios = []
        for mid, dm in demands.items():
            a = allocs.get(mid, {})
            fulfilled = sum(v * min(1.0, capacity / loads[k])
                            for k, v in a.items())
            p = dm - fulfilled
            if not a:
                p = dm
            prios.append((p, mid))
        prios.sort(reverse=True)

        for wid, m in self.c.workers.items():
            for gid in m.gpu_ids():
                g = m.gpus[gid]
                if len(g.loading) >= self.max_loads:
                    continue
                for p, mid in prios:
                    if p <= 0:
                        break
                    if g.pagecache.contains(mid):
                        continue
                    model = self.c.models[mid]
                    pages = model.pages(g.pagecache.page_bytes)
                    if not self._make_room(wid, gid, pages, now):
                        continue
                    load_t = self._load_est(mid)
                    a = Action(type=ActionType.LOAD, model_id=mid,
                               worker_id=wid, gpu_id=gid, earliest=now,
                               latest=now + self.load_window,
                               expected_duration=load_t)
                    self.c.send_action(a)
                    break  # one new LOAD per gpu per tick

    def _make_room(self, wid: str, gid: int, pages: int, now: float) -> bool:
        m = self.c.workers[wid]
        g = m.gpus[gid]
        guard = 0
        while g.pagecache.free_pages < pages and guard < 64:
            guard += 1
            active = set(g.loading)
            # don't evict models with pending demand if avoidable
            busy = {mid for mid, q in self.queues.items() if q}
            victim = g.pagecache.lru_candidate(exclude=active | busy)
            if victim is None:
                victim = g.pagecache.lru_candidate(exclude=active)
            if victim is None:
                return False
            a = Action(type=ActionType.UNLOAD, model_id=victim,
                       worker_id=wid, gpu_id=gid, earliest=now,
                       latest=now + 1.0, expected_duration=1e-5)
            self.c.send_action(a)
        return g.pagecache.free_pages >= pages
