"""Telemetry & profiling subsystem (see DESIGN.md §3).

* events — RequestSpan / ActionRecord / GaugeSample dataclasses
* recorder — ring-buffer Recorder with JSONL export
* profile_store — persistent (action, model, batch) -> latency profiles
* reports — latency breakdowns, prediction-error, Table-1 tables
* profiler — offline profiler CLI (`python -m repro.telemetry.profiler`)
"""
from repro.telemetry.events import ActionRecord, GaugeSample, RequestSpan
from repro.telemetry.profile_store import (LatencyProfile, ProfileStore,
                                           STORE_VERSION)
from repro.telemetry.recorder import Recorder
from repro.telemetry.reports import (gauge_report, latency_breakdown,
                                     latency_quantiles, latency_summary,
                                     load_jsonl, prediction_error_report,
                                     profile_table, summarize_run)

__all__ = [
    "ActionRecord", "GaugeSample", "RequestSpan", "Recorder",
    "LatencyProfile", "ProfileStore", "STORE_VERSION",
    "gauge_report", "latency_breakdown", "latency_quantiles",
    "latency_summary", "load_jsonl", "prediction_error_report",
    "profile_table", "summarize_run",
]
