"""Offline profiler CLI (§5.2 / Table 1).

Sweeps the registered serving models (reduced ResNet + LM decode engines
from `repro.serving.engine`) across their batch buckets, measures
LOAD/INFER durations, writes a versioned ProfileStore, and prints a
Table-1-style report. A serving run started from the written store skips
warmup re-measurement entirely.

Usage:
    PYTHONPATH=src python -m repro.telemetry.profiler \\
        --out experiments/profiles.json [--quick] [--reps 3] \\
        [--models resnet_tiny,qwen2_decode] [--batches 1,2,4] [--merge]
"""
from __future__ import annotations

import argparse
import sys
from typing import Callable, Dict, List, Optional, Tuple

from repro.telemetry.profile_store import ProfileStore
from repro.telemetry.reports import profile_table

Spec = Tuple[str, Callable[[], "object"]]   # (model_id, JaxModel factory)


def default_specs(quick: bool = False,
                  batches: Optional[Tuple[int, ...]] = None) -> List[Spec]:
    """The registered serving models (mirrors benchmarks/table1)."""
    from repro.serving.engine import make_lm_decode_model, make_resnet_model
    rb = batches or (1, 2, 4)
    specs: List[Spec] = [
        ("resnet_tiny", lambda: make_resnet_model(
            "resnet_tiny", scale=16, img=64, batches=rb)),
    ]
    if not quick:
        specs += [
            ("resnet_small", lambda: make_resnet_model(
                "resnet_small", scale=8, img=64, batches=rb)),
            ("qwen2_decode", lambda: make_lm_decode_model(
                "qwen2_decode", "qwen2-0.5b", batches=rb, ctx=128)),
            ("mamba2_decode", lambda: make_lm_decode_model(
                "mamba2_decode", "mamba2-130m", batches=rb, ctx=128)),
        ]
    return specs


def profile_engine(jm, reps: int = 3) -> Dict[Tuple[str, str, int], list]:
    """Measure one JaxModel; returns (action_type, model_id, batch) -> durs."""
    out = {}
    for (t, b), durs in jm.measure(reps=reps).items():
        out[(t, jm.model_id, b)] = durs
    out[("LOAD", jm.model_id, 1)] = jm.measure_load(reps=max(1, reps - 1))
    return out


def build_store(specs: List[Spec], reps: int = 3,
                store: Optional[ProfileStore] = None,
                verbose: bool = False) -> ProfileStore:
    store = store if store is not None else ProfileStore()
    for name, mk in specs:
        if verbose:
            print(f"[profiler] compiling + measuring {name} ...",
                  file=sys.stderr)
        jm = mk()
        for (t, mid, b), durs in profile_engine(jm, reps=reps).items():
            store.update(t, mid, b, durs)
        jm.unload()
    return store


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.profiler", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--out", default="experiments/profiles.json",
                    help="ProfileStore JSON path (default %(default)s)")
    ap.add_argument("--reps", type=int, default=3,
                    help="timed repetitions per batch bucket")
    ap.add_argument("--quick", action="store_true",
                    help="profile only the smallest ResNet")
    ap.add_argument("--models", default=None,
                    help="comma-separated subset of registered model ids")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch buckets (default 1,2,4)")
    ap.add_argument("--merge", action="store_true",
                    help="merge into an existing store instead of rewriting")
    args = ap.parse_args(argv)

    batches = None
    if args.batches:
        try:
            batches = tuple(int(b) for b in args.batches.split(","))
        except ValueError:
            ap.error(f"--batches must be comma-separated ints, "
                     f"got {args.batches!r}")
        if any(b < 1 for b in batches):
            ap.error("--batches entries must be >= 1")
    specs = default_specs(quick=args.quick, batches=batches)
    if args.models:
        want = set(args.models.split(","))
        unknown = want - {n for n, _ in specs}
        if unknown:
            ap.error(f"unknown models {sorted(unknown)}; "
                     f"registered: {[n for n, _ in specs]}")
        specs = [(n, mk) for n, mk in specs if n in want]

    store = (ProfileStore.load_if_exists(args.out) or ProfileStore()) \
        if args.merge else ProfileStore()
    build_store(specs, reps=args.reps, store=store, verbose=True)
    path = store.save(args.out)
    print(f"[profiler] wrote {len(store)} profiles -> {path}")
    bs = batches or (1, 2, 4)
    for line in profile_table(store, batches=bs):
        print(line)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
