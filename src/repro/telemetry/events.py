"""Telemetry event records (§6 "telemetry" / Fig 2, Fig 9 inputs).

Two record types cover everything the paper's figures need:

* RequestSpan — the life of one request through the controller: arrival,
  queue admission, dispatch into an EXEC action, the (optional) cold-start
  LOAD that blocked it, on-device execution, and the response. Spans are
  opened by `Controller.on_request` and closed by `complete`/`reject`.
* ActionRecord — one controller<->worker action round-trip with the
  *predicted* duration (the estimate the scheduler committed to) next to
  the *actual* measured duration. Fig 9's over/under prediction-error CDFs
  are computed from these.

A third, lighter record type carries control-plane health samples:

* GaugeSample — one named scalar measurement at a point in time (e.g. the
  scheduler's per-tick wall latency `scheduler.tick_latency_s`). Gauges
  make control-plane overhead a first-class telemetry stream so perf
  regressions show up in `telemetry_report` and the bench harness.

Records are plain dataclasses with a `to_dict()` for JSONL export; they
deliberately import nothing from `repro.core` so the dependency points
core -> telemetry only.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

NAN = float("nan")


@dataclasses.dataclass
class RequestSpan:
    """Per-request latency breakdown timestamps (all seconds, loop clock)."""
    request_id: int
    model_id: str
    arrival: float
    slo: float
    queued: float = NAN        # controller accepted it into the scheduler
    dispatched: float = NAN    # last EXEC action carrying it was sent
    load_start: float = NAN    # cold-start LOAD that unblocked it (if any)
    load_end: float = NAN
    exec_start: float = NAN    # on-device execution window
    exec_end: float = NAN
    response: float = NAN      # completion/rejection time
    status: Optional[str] = None   # "ok" | "timeout" | "rejected"
    worker_id: Optional[str] = None
    gpu_id: int = -1
    batch_size: int = 0
    attempts: int = 0          # dispatch count (>1 => requeued after reject)
    cold_start: bool = False
    # client-side spans only: the controller-clock [admission, completion]
    # interval echoed back in the RESPONSE. Both stamps share the remote
    # clock, so their difference is skew-free — `net_overhead` is the part
    # of the client-observed latency the controller never saw (network
    # legs + controller-side framing).
    remote_arrival: float = NAN
    remote_completion: float = NAN

    # ---------------------------------------------------------- breakdown
    @property
    def queue_delay(self) -> float:
        ref = self.dispatched if not math.isnan(self.dispatched) \
            else self.response
        return ref - self.arrival

    @property
    def exec_time(self) -> float:
        return self.exec_end - self.exec_start

    @property
    def total(self) -> float:
        return self.response - self.arrival

    @property
    def remote_total(self) -> float:
        """Controller-observed latency (admission -> completion)."""
        return self.remote_completion - self.remote_arrival

    @property
    def net_overhead(self) -> float:
        """Client-observed minus controller-observed latency."""
        return self.total - self.remote_total

    def to_dict(self) -> dict:
        # never-stamped phases export as null, keeping the JSONL strict
        return {k: (None if isinstance(v, float) and math.isnan(v) else v)
                for k, v in dataclasses.asdict(self).items()}

    @classmethod
    def from_dict(cls, d: dict) -> "RequestSpan":
        """Inverse of to_dict (wire decode / JSONL reload): null phase
        stamps come back as NaN."""
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        for k in ("queued", "dispatched", "load_start", "load_end",
                  "exec_start", "exec_end", "response",
                  "remote_arrival", "remote_completion"):
            if kw.get(k) is None:
                kw[k] = NAN
        return cls(**kw)


@dataclasses.dataclass
class ActionRecord:
    """One action's predicted vs actual duration (+ worker-side stamps)."""
    action_id: int
    action_type: str
    model_id: str
    worker_id: str
    gpu_id: int
    batch_size: int
    status: str
    t_received: float          # worker received the action
    t_start: float             # execution began
    t_end: float               # result emitted
    actual: float              # measured on-device duration
    predicted: Optional[float] = None   # scheduler's committed estimate
    request_ids: Tuple[int, ...] = ()

    @property
    def error(self) -> Optional[float]:
        """predicted - actual; positive => over-prediction (actual faster)."""
        if self.predicted is None:
            return None
        return self.predicted - self.actual

    @property
    def worker_queue_delay(self) -> float:
        return self.t_start - self.t_received

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ActionRecord":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw["request_ids"] = tuple(kw.get("request_ids", ()))
        return cls(**kw)


@dataclasses.dataclass
class GaugeSample:
    """One named scalar sample (loop-clock timestamp, measured value)."""
    name: str
    t: float
    value: float

    def to_dict(self) -> dict:
        return {"name": self.name, "t": self.t, "value": self.value}

    @classmethod
    def from_dict(cls, d: dict) -> "GaugeSample":
        return cls(name=d["name"], t=d["t"], value=d["value"])
