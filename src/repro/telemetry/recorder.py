"""Low-overhead telemetry recorder (ring buffers + JSONL export).

The Recorder is the single sink for the controller's telemetry stream.
Hot-path cost is one dict lookup plus attribute writes per event; storage
is two bounded deques (ring buffers), so a sustained run can never grow
memory without bound — old records are dropped and counted instead.

Event flow (see DESIGN.md §3):

    on_request ──► span_open
    send_action ─► span_dispatch          (EXEC actions carrying requests)
    on_result ───► record_action          (every result => ActionRecord)
               ├─► span_exec              (successful EXEC)
               └─► span_load              (successful LOAD => cold-start
                                           attribution to waiting spans)
    complete/reject ─► span_close
    scheduler.tick ──► record_gauge       (per-tick control-plane latency)
"""
from __future__ import annotations

import collections
import json
import math
import os
from typing import Dict, Iterable, Optional

from repro.telemetry.events import ActionRecord, GaugeSample, RequestSpan


class Recorder:
    def __init__(self, capacity: int = 65536):
        self.capacity = capacity
        self.actions: collections.deque = collections.deque(maxlen=capacity)
        self.spans: collections.deque = collections.deque(maxlen=capacity)
        self.gauges: Dict[str, collections.deque] = {}
        self._open: Dict[int, RequestSpan] = {}
        # per-model view of _open so LOAD attribution touches only the
        # spans of the loaded model, not every open span in the system
        self._open_by_model: Dict[str, Dict[int, RequestSpan]] = {}
        self.dropped_actions = 0
        self.dropped_spans = 0
        self.dropped_gauges = 0
        # continuous JSONL streaming (stream_to): long-running daemons
        # write records as they close instead of one end-of-run export
        self._stream_f = None
        self._stream_path: Optional[str] = None
        self._stream_bytes = 0
        self._rotate_bytes: Optional[int] = None
        self._rotate_keep = 4
        self.stream_lines = 0
        self.stream_rotations = 0

    # ------------------------------------------------------------- spans
    def span_open(self, req, queued: float):
        """Open a span at controller admission. `req` is duck-typed
        (needs id/model_id/arrival/slo)."""
        s = RequestSpan(
            request_id=req.id, model_id=req.model_id, arrival=req.arrival,
            slo=req.slo, queued=queued)
        self._open[req.id] = s
        per_model = self._open_by_model.get(req.model_id)
        if per_model is None:
            per_model = self._open_by_model[req.model_id] = {}
        per_model[req.id] = s

    def span_dispatch(self, request_ids, when: float, worker_id: str,
                      gpu_id: int, batch_size: int):
        for rid in request_ids:
            s = self._open.get(rid)
            if s is None:
                continue
            s.dispatched = when
            s.worker_id = worker_id
            s.gpu_id = gpu_id
            s.batch_size = batch_size
            s.attempts += 1

    def span_exec(self, request_ids, t_start: float, t_end: float):
        for rid in request_ids:
            s = self._open.get(rid)
            if s is not None:
                s.exec_start = t_start
                s.exec_end = t_end

    def span_remote(self, request_id: int, arrival, completion):
        """Stamp the controller-side [admission, completion] interval onto
        an open *client-side* span (the RESPONSE echoes both stamps). Both
        stamps share the controller clock, so their difference — and thus
        the span's `net_overhead` — is immune to client/controller skew."""
        s = self._open.get(request_id)
        if s is None or arrival is None or completion is None:
            return
        s.remote_arrival = arrival
        s.remote_completion = completion

    def span_load(self, model_id: str, t_start: float, t_end: float):
        """Attribute a completed LOAD to the requests it unblocked: open
        spans of that model still waiting to be dispatched. Already-
        dispatched spans were served by an existing replica — a
        replication LOAD elsewhere is not their cold start."""
        for s in self._open_by_model.get(model_id, {}).values():
            if math.isnan(s.dispatched) and math.isnan(s.load_start):
                s.load_start = t_start
                s.load_end = t_end
                s.cold_start = True

    def span_close(self, req, when: float):
        s = self._open.pop(req.id, None)
        if s is None:
            return None
        per_model = self._open_by_model.get(s.model_id)
        if per_model is not None:
            per_model.pop(req.id, None)
            if not per_model:
                del self._open_by_model[s.model_id]
        s.response = when
        s.status = req.status
        if len(self.spans) == self.capacity:
            self.dropped_spans += 1
        self.spans.append(s)
        if self._stream_f is not None:
            self._stream_write("span", s.to_dict())
        return s

    # ----------------------------------------------------------- actions
    def record_action(self, result, predicted: Optional[float]):
        """Build an ActionRecord from a worker Result (duck-typed)."""
        if len(self.actions) == self.capacity:
            self.dropped_actions += 1
        rec = ActionRecord(
            action_id=result.action_id,
            action_type=getattr(result.action_type, "value",
                                str(result.action_type)),
            model_id=result.model_id, worker_id=result.worker_id,
            gpu_id=result.gpu_id, batch_size=result.batch_size,
            status=getattr(result.status, "value", str(result.status)),
            t_received=getattr(result, "t_received", 0.0),
            t_start=result.t_start, t_end=result.t_end,
            actual=result.duration, predicted=predicted,
            request_ids=tuple(result.request_ids))
        self.actions.append(rec)
        if self._stream_f is not None:
            self._stream_write("action", rec.to_dict())
        return rec

    # ------------------------------------------------------------ gauges
    def record_gauge(self, name: str, t: float, value: float) -> None:
        """Append one named control-plane sample (e.g. scheduler tick
        latency). One dict lookup + deque append on the hot path."""
        dq = self.gauges.get(name)
        if dq is None:
            dq = self.gauges[name] = collections.deque(maxlen=self.capacity)
        if len(dq) == self.capacity:
            self.dropped_gauges += 1
        g = GaugeSample(name=name, t=t, value=value)
        dq.append(g)
        if self._stream_f is not None:
            self._stream_write("gauge", g.to_dict())

    def iter_gauges(self, name: Optional[str] = None):
        if name is not None:
            return iter(self.gauges.get(name, ()))
        return (g for dq in self.gauges.values() for g in dq)

    # --------------------------------------------------------- streaming
    def stream_to(self, path: str, rotate_bytes: Optional[int] = None,
                  rotate_keep: int = 4) -> None:
        """Continuously append every closed span / action record / gauge
        sample to `path` as JSONL. When `rotate_bytes` is set and the live
        file exceeds it, the file rotates (`path` -> `path.1` -> ... ->
        `path.<rotate_keep>`, oldest dropped) — so a long-running daemon's
        telemetry never grows one file without bound."""
        self.close_stream()
        self._stream_path = path
        self._rotate_bytes = rotate_bytes
        self._rotate_keep = max(1, rotate_keep)
        # binary mode: the rotation bound counts encoded bytes, and tell()
        # on an append stream is the true file size
        self._stream_f = open(path, "ab")
        self._stream_bytes = self._stream_f.tell()

    def _stream_write(self, kind: str, d: dict) -> None:
        # allow_nan: best-effort spans carry slo=inf (Python JSON extension)
        data = (json.dumps({"kind": kind, **d}, separators=(",", ":"),
                           allow_nan=True) + "\n").encode("utf-8")
        self._stream_f.write(data)
        self._stream_bytes += len(data)
        self.stream_lines += 1
        if self._rotate_bytes is not None \
                and self._stream_bytes >= self._rotate_bytes:
            self._rotate()

    def _rotate(self) -> None:
        self._stream_f.close()
        path = self._stream_path
        oldest = f"{path}.{self._rotate_keep}"
        if os.path.exists(oldest):
            os.remove(oldest)
        for k in range(self._rotate_keep - 1, 0, -1):
            src = f"{path}.{k}"
            if os.path.exists(src):
                os.replace(src, f"{path}.{k + 1}")
        os.replace(path, f"{path}.1")
        self._stream_f = open(path, "wb")
        self._stream_bytes = 0
        self.stream_rotations += 1

    def close_stream(self) -> None:
        """Flush and stop streaming (daemon shutdown path)."""
        if self._stream_f is not None:
            self._stream_f.close()
            self._stream_f = None

    # ------------------------------------------------------------ export
    def iter_actions(self) -> Iterable[ActionRecord]:
        return iter(self.actions)

    def iter_spans(self) -> Iterable[RequestSpan]:
        return iter(self.spans)

    def export_jsonl(self, path: str) -> int:
        """Write closed spans + action records as JSONL; returns #lines."""
        n = 0
        with open(path, "w") as f:
            for s in self.spans:
                f.write(json.dumps({"kind": "span", **s.to_dict()},
                                   allow_nan=False) + "\n")
                n += 1
            for a in self.actions:
                f.write(json.dumps({"kind": "action", **a.to_dict()},
                                   allow_nan=False) + "\n")
                n += 1
            for g in self.iter_gauges():
                f.write(json.dumps({"kind": "gauge", **g.to_dict()},
                                   allow_nan=False) + "\n")
                n += 1
        return n

    def clear(self):
        self.actions.clear()
        self.spans.clear()
        self.gauges.clear()
        self._open.clear()
        self._open_by_model.clear()
