"""Telemetry reports: latency breakdowns, prediction-error summaries, and
Table-1-style profile tables.

These are the *single* aggregation path for the repo's figures:
`benchmarks/fig2_predictability.py` uses `latency_quantiles`/
`latency_summary`, `benchmarks/fig9_prediction_error.py` uses
`prediction_error_report` over Recorder action records, and
`serving/simulator.py` exposes `summarize_run`.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Sequence, Tuple


def quantile(xs: Sequence[float], q: float) -> float:
    xs = sorted(xs)
    if not xs:
        return float("nan")
    return xs[min(len(xs) - 1, int(q * (len(xs) - 1)))]


def latency_quantiles(lats: Sequence[float],
                      qs: Sequence[float] = (0.5, 0.9, 0.99, 0.999, 1.0)
                      ) -> List[Tuple[float, float]]:
    return [(q, quantile(lats, q)) for q in qs]


def latency_summary(lats: Sequence[float]) -> dict:
    med = quantile(lats, 0.5)
    p99 = quantile(lats, 0.99)
    return {"count": len(lats), "median": med, "p99": p99,
            "p999": quantile(lats, 0.999),
            "max": max(lats) if lats else float("nan"),
            "p99_over_median": p99 / med if lats and med > 0
            else float("nan")}


# ------------------------------------------------------------------ spans
def latency_breakdown(spans: Iterable) -> dict:
    """Phase-by-phase latency stats over closed RequestSpans.

    Returns {"total": {...}, "queue": {...}, "exec": {...}} summaries over
    requests that completed ok, plus status/cold-start counts.
    """
    total, queue, execs = [], [], []
    statuses: Dict[str, int] = {}
    cold = 0
    for s in spans:
        statuses[s.status or "open"] = statuses.get(s.status or "open", 0) + 1
        if s.cold_start:
            cold += 1
        if s.status != "ok":
            continue
        total.append(s.total)
        if not math.isnan(s.queue_delay):
            queue.append(s.queue_delay)
        if not math.isnan(s.exec_time):
            execs.append(s.exec_time)
    return {"total": latency_summary(total),
            "queue": latency_summary(queue),
            "exec": latency_summary(execs),
            "statuses": statuses, "cold_starts": cold}


def client_breakdown(spans: Iterable) -> dict:
    """Client-side view over RemoteClient spans: client-observed latency,
    the controller-observed portion echoed back in each RESPONSE, and the
    network/framing overhead between the two (skew-free per request —
    see RequestSpan.net_overhead). This is the third-tier complement of
    `latency_breakdown`: the controller's report says how long serving
    took, this one says how long the *client waited*."""
    total, remote, net = [], [], []
    statuses: Dict[str, int] = {}
    for s in spans:
        statuses[s.status or "open"] = statuses.get(s.status or "open", 0) + 1
        if s.status != "ok":
            continue
        total.append(s.total)
        if not math.isnan(s.remote_total):
            remote.append(s.remote_total)
            net.append(s.net_overhead)
    return {"client_total": latency_summary(total),
            "controller_total": latency_summary(remote),
            "net_overhead": latency_summary(net),
            "statuses": statuses}


# ---------------------------------------------------------------- actions
def prediction_error_report(records: Iterable) -> dict:
    """Fig-9 over/under prediction-error stats from ActionRecords."""
    over, under = [], []
    for a in records:
        if a.status != "SUCCESS" or a.predicted is None or a.actual <= 0:
            continue
        err = a.predicted - a.actual
        (over if err >= 0 else under).append(abs(err))

    def stats(xs):
        return {"n": len(xs),
                "p99_us": (quantile(xs, 0.99) * 1e6) if xs else 0.0,
                "max_us": (max(xs) * 1e6) if xs else 0.0}

    return {"over": stats(over), "under": stats(under)}


# ----------------------------------------------------------------- gauges
def gauge_report(recorder) -> dict:
    """Summary stats per control-plane gauge (scheduler tick latency &c)."""
    out = {}
    for name, dq in getattr(recorder, "gauges", {}).items():
        xs = [g.value for g in dq]
        out[name] = {"n": len(xs),
                     "mean": (sum(xs) / len(xs)) if xs else float("nan"),
                     "p50": quantile(xs, 0.50), "p99": quantile(xs, 0.99),
                     "max": max(xs) if xs else float("nan")}
    return out


def summarize_run(recorder) -> dict:
    """One-call run summary: latency breakdown + prediction error +
    control-plane gauges."""
    return {"breakdown": latency_breakdown(recorder.iter_spans()),
            "prediction_error": prediction_error_report(
                recorder.iter_actions()),
            "gauges": gauge_report(recorder)}


# ------------------------------------------------------------------ jsonl
def load_jsonl(path: str) -> dict:
    """Reload a Recorder JSONL file (end-of-run `export_jsonl` or a
    `stream_to` file/rotation) into typed records, so offline analysis of
    a daemon's telemetry stream can reuse `latency_breakdown` /
    `prediction_error_report` unchanged."""
    from repro.telemetry.events import (ActionRecord, GaugeSample,
                                        RequestSpan)
    out = {"spans": [], "actions": [], "gauges": []}
    with open(path) as f:
        for line in f:
            d = json.loads(line)
            kind = d.pop("kind", None)
            if kind == "span":
                out["spans"].append(RequestSpan.from_dict(d))
            elif kind == "action":
                out["actions"].append(ActionRecord.from_dict(d))
            elif kind == "gauge":
                out["gauges"].append(GaugeSample.from_dict(d))
    return out


# ------------------------------------------------------------------ store
def profile_table(store, batches: Sequence[int] = (1, 2, 4, 8, 16)
                  ) -> List[str]:
    """Table-1-style report lines for a ProfileStore."""
    cols = "".join(f"  b{b}_ms" for b in batches)
    lines = [f"{'model':<24}  load_ms{cols}"]
    for mid in store.model_ids():
        load = store.get("LOAD", mid, 1)
        cells = [f"{load.median_s * 1e3:7.2f}" if load else f"{'—':>7}"]
        for b in batches:
            p = store.get("INFER", mid, b) or store.get("DECODE", mid, b)
            cells.append(f"{p.median_s * 1e3:6.2f}" if p else f"{'—':>6}")
        lines.append(f"{mid:<24}  " + " ".join(cells))
    return lines
