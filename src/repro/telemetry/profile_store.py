"""Persistent action-profile store (§5.2 offline profiling, Table 1).

Clockwork seeds its scheduler with latency profiles measured *offline*,
then refines them online. This module is the persistence layer: a
versioned JSON file mapping (action_type, model_id, batch) to a latency
profile (count/median/p99/max seconds). It is written by the offline
profiler CLI (`python -m repro.telemetry.profiler`) and by shutdown
updates from live telemetry, and read at startup to seed ActionProfiler —
so repeat runs skip warmup re-measurement entirely.

File format (STORE_VERSION = 1):

    {"version": 1,
     "entries": [{"action_type": "INFER", "model_id": "resnet_tiny",
                  "batch": 1, "count": 12, "median_s": 0.0021,
                  "p99_s": 0.0024, "max_s": 0.0025}, ...]}
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, Iterable, Optional, Sequence, Tuple

from repro.telemetry.reports import quantile

STORE_VERSION = 1

Key = Tuple[str, str, int]          # (action_type, model_id, batch)


@dataclasses.dataclass
class LatencyProfile:
    count: int
    median_s: float
    p99_s: float
    max_s: float

    @classmethod
    def from_durations(cls, durs: Sequence[float]) -> "LatencyProfile":
        if not durs:
            raise ValueError("empty duration list")
        return cls(count=len(durs), median_s=quantile(durs, 0.5),
                   p99_s=quantile(durs, 0.99), max_s=max(durs))

    def merged(self, other: "LatencyProfile") -> "LatencyProfile":
        """Approximate merge: medians are count-weighted, tails take max."""
        n = self.count + other.count
        med = (self.median_s * self.count + other.median_s * other.count) / n
        return LatencyProfile(count=n, median_s=med,
                              p99_s=max(self.p99_s, other.p99_s),
                              max_s=max(self.max_s, other.max_s))

    @property
    def estimate(self) -> float:
        """Conservative seed estimate (matches the predictor's window-max)."""
        return self.max_s


class ProfileStore:
    def __init__(self):
        self.profiles: Dict[Key, LatencyProfile] = {}

    # -------------------------------------------------------------- CRUD
    def put(self, action_type: str, model_id: str, batch: int,
            profile: LatencyProfile):
        self.profiles[(action_type, model_id, batch)] = profile

    def get(self, action_type: str, model_id: str,
            batch: int) -> Optional[LatencyProfile]:
        return self.profiles.get((action_type, model_id, batch))

    def update(self, action_type: str, model_id: str, batch: int,
               durations: Sequence[float]):
        """Merge a batch of measured durations into the stored profile."""
        if not durations:
            return
        new = LatencyProfile.from_durations(durations)
        key = (action_type, model_id, batch)
        old = self.profiles.get(key)
        self.profiles[key] = new if old is None else old.merged(new)

    def __len__(self) -> int:
        return len(self.profiles)

    def items(self):
        return self.profiles.items()

    def model_ids(self):
        return sorted({mid for (_, mid, _) in self.profiles})

    # ----------------------------------------------------- telemetry I/O
    def update_from_recorder(self, recorder):
        """Fold successful ActionRecords from a live run into the store."""
        by_key: Dict[Key, list] = {}
        for a in recorder.iter_actions():
            if a.status == "SUCCESS" and a.actual > 0:
                by_key.setdefault(
                    (a.action_type, a.model_id, a.batch_size),
                    []).append(a.actual)
        for (t, mid, b), durs in by_key.items():
            self.update(t, mid, b, durs)

    def update_from_profiler(self, profiler):
        """Fold an ActionProfiler's observation windows into the store."""
        for (t, mid, b), durs in profiler.history().items():
            self.update(t, mid, b, durs)

    def seed_profiler(self, profiler):
        """Seed an ActionProfiler with the conservative stored estimates."""
        for (t, mid, b), p in self.profiles.items():
            profiler.seed(t, mid, b, p.estimate)

    def seed_dict(self) -> Dict[Key, float]:
        """(action_type, model_id, batch) -> seconds, the format
        `Controller.add_worker(profiles=...)` accepts."""
        return {k: p.estimate for k, p in self.profiles.items()}

    # ------------------------------------------------------- persistence
    def save(self, path: str) -> str:
        entries = [{"action_type": t, "model_id": mid, "batch": b,
                    **dataclasses.asdict(p)}
                   for (t, mid, b), p in sorted(self.profiles.items())]
        payload = {"version": STORE_VERSION, "entries": entries}
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        # atomic write: a crashed profiler never leaves a torn store
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path

    @classmethod
    def load(cls, path: str) -> "ProfileStore":
        with open(path) as f:
            payload = json.load(f)
        version = payload.get("version")
        if version != STORE_VERSION:
            raise ValueError(
                f"profile store {path}: version {version!r}, "
                f"expected {STORE_VERSION}")
        store = cls()
        for e in payload["entries"]:
            store.put(e["action_type"], e["model_id"], int(e["batch"]),
                      LatencyProfile(count=int(e["count"]),
                                     median_s=float(e["median_s"]),
                                     p99_s=float(e["p99_s"]),
                                     max_s=float(e["max_s"])))
        return store

    @classmethod
    def load_if_exists(cls, path: str) -> Optional["ProfileStore"]:
        return cls.load(path) if os.path.exists(path) else None
